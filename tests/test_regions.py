"""Multi-region fleets under a spot-price market.

The geographic axis, end to end: region-tagged ``ReplicaProfile``s, the
``FleetPlan`` RTT matrix injected into the fabric as a deterministic
virtual-clock ``DelayedReplica`` shim, region-aware interactive placement
(``region_spills`` when forced cross-region), the seeded ``SpotMarket``
pricing the spot leg of the planner's cost model per tick, and the fleet
event counters (preemptions / tier_spills / region_spills) riding the
collector → trace → DNN feature stream as real per-tick channels.

Compatibility pins (each verified failing on the pre-region src where it
guards new behavior): a region-less fleet routes bit-identically to the
pre-region profiled key — no delay shims, no spill counting, identical
placement sequence — and untagged requests skip the preference entirely.
"""
import dataclasses
import functools

import numpy as np
import pytest

from repro.serving import ReplicaRouter, Request, ServingEngine
from repro.serving.chaos import DelayedReplica
from repro.serving.engine import EngineCore
from repro.serving.profiles import (
    DEFAULT_RTT_MS, FleetPlan, ReplicaProfile, SpotMarket, rtt_between,
)

from conftest import TINY_CFGS

CFG = TINY_CFGS["dense"]
MAX_SEQ = 24
SLOTS = 2


@functools.lru_cache(maxsize=None)
def shared_core():
    return EngineCore(CFG, MAX_SEQ, seed=0)


def make_router(n_replicas=2, max_replicas=4, profile_fn=None, **kw):
    core = shared_core()

    def factory(replica_id):
        return ServingEngine(CFG, slots=SLOTS, max_seq=MAX_SEQ,
                             prefill_chunk=4, core=core,
                             replica_id=replica_id)

    if profile_fn is not None:
        kw["profile_fn"] = profile_fn
    return ReplicaRouter(factory, n_replicas=n_replicas,
                         max_replicas=max_replicas, **kw)


def req(rid, *, region="", tier="interactive", prompt_len=6, gen_len=3):
    rng = np.random.default_rng(rid)
    kw = {} if tier == "interactive" else {"tier": tier}
    # region kwarg only when tagged, so the compatibility pins construct
    # pre-region Requests (which predate the field) unchanged
    if region:
        kw["region"] = region
    return Request(rid=rid,
                   prompt=rng.integers(3, CFG.vocab,
                                       size=prompt_len).astype(np.int32),
                   gen_len=gen_len, **kw)


# ------------------------------------------------------- profiles & market


def test_rtt_between_symmetric_same_region_free():
    assert rtt_between("na", "apac") == rtt_between("apac", "na") == 150.0
    assert rtt_between("na", "na") == 0.0
    assert rtt_between("", "apac") == rtt_between("na", "") == 0.0
    assert rtt_between("na", "atlantis") == 0.0      # unknown region: free
    assert rtt_between("na", "apac", {("apac", "na"): 42.0}) == 42.0


def test_fleet_plan_stripes_regions_and_injects_rtt():
    plan = FleetPlan(reserved=2, regions=("na", "apac"))
    assert [plan.region_of(i) for i in range(4)] == \
        ["na", "apac", "na", "apac"]
    assert plan.origin == "na"                       # defaults to regions[0]
    assert plan.transport_ms_for(0) == 0.0           # in-region: free
    assert plan.transport_ms_for(1) == DEFAULT_RTT_MS[("na", "apac")]
    assert plan.profile_for(1).region == "apac"
    assert plan.profile_for(1).preemptible is False  # id 1 < reserved
    assert plan.profile_for(2).preemptible is True
    # home_region overrides the vantage point
    far = dataclasses.replace(plan, home_region="eu")
    assert far.origin == "eu"
    assert far.transport_ms_for(0) == DEFAULT_RTT_MS[("na", "eu")]
    # region-less plan: no geography anywhere
    flat = FleetPlan(reserved=2)
    assert flat.origin == "" and flat.transport_ms_for(3) == 0.0
    assert flat.profile_for(0).region == ""


def test_spot_market_seed_deterministic_and_order_independent():
    a, b = SpotMarket(seed=7), SpotMarket(seed=7)
    fwd = [a.price(t) for t in range(40)]
    rev = [b.price(t) for t in reversed(range(40))]
    assert fwd == list(reversed(rev))                # cache, not query order
    assert all(p >= SpotMarket().floor for p in fwd)
    assert SpotMarket(seed=8).prices(40) != fwd      # the seed matters
    assert a.price(0) == a.base                      # tick 0 is the base


def test_spot_market_spike_lifts_price_above_on_demand():
    # spike_prob=1 forces a spike immediately: the marginal spot replica
    # briefly costs MORE than on-demand — what the planner must see
    m = SpotMarket(seed=0, spike_prob=1.0)
    plan = FleetPlan(reserved=1, market=m)
    spiked = max(m.prices(8))
    assert spiked >= m.base * m.spike_mult * 0.5
    assert plan.spot_price(1) == m.price(1)
    assert plan.spot_price(None) == plan.cost_preemptible   # no tick: flat


def test_cost_of_prices_spot_leg_at_market_rate():
    m = SpotMarket(seed=3)
    plan = FleetPlan(reserved=2, cost_on_demand=1.0, market=m)
    for tick in (0, 5, 17):
        assert plan.cost_of(5, tick) == pytest.approx(
            2 * 1.0 + 3 * m.price(tick))
        # price_of decomposes cost_of exactly
        assert plan.cost_of(5, tick) == pytest.approx(
            sum(plan.price_of(i, tick) for i in range(5)))
    # backward compatible: no tick (or no market) → the catalog constant
    assert plan.cost_of(5) == pytest.approx(2 * 1.0 + 3 * 0.35)
    assert FleetPlan(reserved=2).cost_of(5, 17) == pytest.approx(
        2 * 1.0 + 3 * 0.35)


# ----------------------------------------------------------- DelayedReplica


def test_delayed_replica_holds_ingress_until_rtt_elapses():
    from repro.serving import InProcessReplica

    rep = InProcessReplica(ServingEngine(
        CFG, slots=SLOTS, max_seq=MAX_SEQ, prefill_chunk=4,
        core=shared_core(), replica_id=0))
    shim = DelayedReplica(rep, rtt_ms=500.0)
    shim.submit(req(0), now=0.0)
    assert shim.pending == 1 and rep.pending == 0    # parked in ingress
    assert shim.load > 0.0                           # routing sees the work
    done = shim.step(0.2)                            # rtt not yet elapsed
    assert done == [] and rep.pending == 0
    done = shim.step(0.6)                            # 0.5s rtt has passed
    assert rep.pending + len(done) >= 1              # delivered inward
    now = 0.6
    while not done and now < 30:
        now += 1.0
        done.extend(shim.step(now))
    assert [r.rid for r in done] == [0]
    # the completion's engine-side latency includes the full round trip
    assert done[0].t_done - done[0].t_submit >= 0.5
    assert shim.transport_ms == rep.transport_ms + 500.0
    assert shim.report(0).transport_ms >= 500.0
    shim.close()


def test_delayed_replica_evacuates_ingress_exactly_once():
    from repro.serving import InProcessReplica

    rep = InProcessReplica(ServingEngine(
        CFG, slots=SLOTS, max_seq=MAX_SEQ, prefill_chunk=4,
        core=shared_core(), replica_id=0))
    shim = DelayedReplica(rep, rtt_ms=1000.0)
    shim.submit(req(0), now=0.0)
    shim.submit(req(1), now=0.0)
    assert shim.queue_depth == 2
    out = shim.evacuate()
    assert sorted(r.rid for r in out) == [0, 1]
    assert shim.evacuate() == [] and shim.lost_requests() == []
    assert shim.idle
    shim.close()


def test_router_shims_remote_replicas_only():
    """from a FleetPlan with regions, the router builds every CROSS-region
    replica behind a DelayedReplica carrying the matrix RTT; in-region
    (and region-less) replicas stay bare."""
    plan = FleetPlan(reserved=2, regions=("na", "apac"))
    router = make_router(n_replicas=2, profile_fn=plan)
    try:
        by_id = {r.replica_id: r for r in router.replicas}
        assert not isinstance(by_id[0], DelayedReplica)      # home region
        assert isinstance(by_id[1], DelayedReplica)
        assert by_id[1].rtt_ms == DEFAULT_RTT_MS[("na", "apac")]
    finally:
        router.close()
    flat = make_router(n_replicas=2, profile_fn=FleetPlan(reserved=2))
    try:
        assert not any(isinstance(r, DelayedReplica) for r in flat.replicas)
    finally:
        flat.close()


# ---------------------------------------------------------- region routing


def test_region_aware_prefers_local_stable_replica():
    """Blind least-load would alternate; aware keeps interactive in-region
    while the local replica has headroom (load < 1)."""
    plan = FleetPlan(reserved=2, regions=("na", "apac"))
    router = make_router(n_replicas=2, profile_fn=plan)
    try:
        by_id = {r.replica_id: r for r in router.replicas}
        router.submit(req(0, region="na"), now=0.0)
        # replica 0 now busier than replica 1 — the legacy key would pick 1
        router.submit(req(1, region="na"), now=0.0)
        assert by_id[0].pending == 2 and by_id[1].pending == 0
        assert router.region_spills == 0
        assert router.metrics()["region_spills"] == 0
    finally:
        router.close()


def test_region_spill_counted_when_local_region_saturated():
    plan = FleetPlan(reserved=2, regions=("na", "apac"))
    router = make_router(n_replicas=2, profile_fn=plan)
    try:
        by_id = {r.replica_id: r for r in router.replicas}
        for i in range(SLOTS):                       # fill na to load 1.0
            router.submit(req(i, region="na"), now=0.0)
        assert by_id[0].load >= 1.0
        router.submit(req(99, region="na"), now=0.0)
        assert by_id[1].pending == 1                 # forced cross-region
        assert router.region_spills == 1
    finally:
        router.close()


def test_region_blind_router_keeps_injected_rtt_but_legacy_key():
    """The ablation's control arm: region_aware=False still builds the
    delay shims (latency stays injected) but routes on the pre-region
    key — and counts no spills."""
    plan = FleetPlan(reserved=2, regions=("na", "apac"))
    router = make_router(n_replicas=2, profile_fn=plan, region_aware=False)
    try:
        by_id = {r.replica_id: r for r in router.replicas}
        assert isinstance(by_id[1], DelayedReplica)  # rtt still injected
        router.submit(req(0, region="na"), now=0.0)
        router.submit(req(1, region="na"), now=0.0)
        assert by_id[0].pending == 1 and by_id[1].pending == 1
        assert router.region_spills == 0
    finally:
        router.close()


def test_untagged_requests_route_on_legacy_key():
    plan = FleetPlan(reserved=2, regions=("na", "apac"))
    router = make_router(n_replicas=2, profile_fn=plan)
    try:
        by_id = {r.replica_id: r for r in router.replicas}
        router.submit(req(0), now=0.0)               # no region tag
        router.submit(req(1), now=0.0)
        assert by_id[0].pending == 1 and by_id[1].pending == 1
        assert router.region_spills == 0
    finally:
        router.close()


def test_regionless_fleet_placement_bit_identical_to_legacy_key():
    """COMPATIBILITY PIN: a profiled fleet whose plan carries no regions
    places a tagged-request stream exactly like the pre-region profiled
    key (same placements, no shims, no spills) — the region machinery is
    provably inert until the operator buys geography."""
    placements = {}
    for name, plan in (("flat", FleetPlan(reserved=4)),
                       ("geo-blind-tags", FleetPlan(reserved=4))):
        router = make_router(n_replicas=3, profile_fn=plan)
        try:
            seq = []
            for i in range(9):
                region = "na" if name == "geo-blind-tags" else ""
                router.submit(req(i, region=region), now=float(i) * 0.01)
                seq.append(tuple(sorted(
                    (r.replica_id, r.pending) for r in router.replicas)))
            placements[name] = seq
            assert router.region_spills == 0
            assert not any(isinstance(r, DelayedReplica)
                           for r in router.replicas)
        finally:
            router.close()
    # tagging requests against a region-less plan changes NOTHING
    assert placements["flat"] == placements["geo-blind-tags"]


# ----------------------------------------- collector / features / traces


def test_collector_fleet_channels_emit_per_tick_deltas():
    from repro.core.monitoring.collector import (
        FLEET_EVENT_KEYS, MetricsCollector,
    )

    assert FLEET_EVENT_KEYS == ("preemptions", "tier_spills",
                                "region_spills")
    c = MetricsCollector()
    c.observe_fleet({"preemptions": 2, "tier_spills": 5,
                     "region_spills": 1})
    rec = c.aggregate(0, n_replicas=1, max_replicas=4)
    assert (rec["preemptions"], rec["tier_spills"],
            rec["region_spills"]) == (2.0, 5.0, 1.0)
    # lifetime totals advance → the NEXT tick sees only the delta
    c.observe_fleet({"preemptions": 2, "tier_spills": 9,
                     "region_spills": 1})
    rec = c.aggregate(1, n_replicas=1, max_replicas=4)
    assert (rec["preemptions"], rec["tier_spills"],
            rec["region_spills"]) == (0.0, 4.0, 0.0)
    # no observe this tick → zero, never a stale repeat; and a counter
    # that (impossibly) went backwards clamps at zero, not negative
    c.observe_fleet({"tier_spills": 3})
    rec = c.aggregate(2, n_replicas=1, max_replicas=4)
    assert rec["tier_spills"] == 0.0 and rec["preemptions"] == 0.0


def test_collector_without_observe_fleet_emits_zero_channels():
    from repro.core.monitoring.collector import MetricsCollector

    rec = MetricsCollector().aggregate(0, n_replicas=1, max_replicas=4)
    for k in ("preemptions", "tier_spills", "region_spills"):
        assert rec[k] == 0.0


def test_feature_registry_carries_fleet_event_channels():
    from repro.core.dnn.features import PERF_KEYS, RESOURCE_KEYS
    from repro.core.dnn.model import DNNConfig

    assert "preemptions" in RESOURCE_KEYS and len(RESOURCE_KEYS) == 9
    assert "tier_spills" in PERF_KEYS and "region_spills" in PERF_KEYS
    assert len(PERF_KEYS) == 10
    # model widths derive from the registry — a fresh DNN is born with
    # the new channels
    cfg = DNNConfig()
    assert cfg.n_resource_features == len(RESOURCE_KEYS)
    assert cfg.n_perf_features == len(PERF_KEYS)


def test_fleet_events_ride_collector_to_streams():
    """The full path: router counters → observe_fleet → aggregate record →
    StreamBuilder window, with the channel landing in the right column."""
    from repro.core.dnn.features import (
        PERF_KEYS, RESOURCE_KEYS, StreamBuilder,
    )
    from repro.core.monitoring.collector import MetricsCollector

    c = MetricsCollector()
    sb = StreamBuilder(window=4)
    for tick, spills in enumerate((0, 3, 3, 7)):
        c.observe_fleet({"preemptions": 1 if tick else 0,
                         "tier_spills": 0, "region_spills": spills})
        sb.push(c.aggregate(tick, n_replicas=1, max_replicas=4))
    streams = sb.streams(np.zeros(12, np.float32))
    assert streams["resource"].shape == (1, 4, len(RESOURCE_KEYS))
    assert streams["perf"].shape == (1, 4, len(PERF_KEYS))
    # un-normalized history holds the per-tick deltas in the right column
    col = PERF_KEYS.index("region_spills")
    assert [row[col] for row in sb.perf_hist] == [0.0, 3.0, 0.0, 4.0]
    pcol = RESOURCE_KEYS.index("preemptions")
    assert [row[pcol] for row in sb.res_hist] == [0.0, 1.0, 0.0, 0.0]


# ------------------------------------------------------------- closed loop


def test_closed_loop_regions_and_market_reach_the_recorder():
    """A tiny regioned spot-market run: the spot price, the per-tick event
    channels, and the lifetime totals all land in the trace records, the
    TickLog carries region_spills, and the plan's market prices the
    optimizer's cost model."""
    from repro.core.dnn.traces import TraceRecorder
    from repro.serving.closed_loop import LoopConfig, run_closed_loop

    lc = LoopConfig(slots=2, max_replicas=2, max_seq=32, prefill_chunk=4,
                    steps_per_tick=6, reserved_replicas=1,
                    regions=("na", "apac"), spot_market=True)
    rec = TraceRecorder()
    router, logs = run_closed_loop(CFG, autoscale=True, ticks=6, seed=0,
                                   lc=lc, recorder=rec)
    try:
        m = SpotMarket(seed=0, base=lc.cost_preemptible)
        assert [r["spot_price"] for r in rec.records] == \
            pytest.approx([m.price(t) for t in range(6)])
        for r in rec.records:
            for k in ("preemptions", "tier_spills", "region_spills",
                      "preemptions_total", "tier_spills_total",
                      "region_spills_total"):
                assert k in r
        assert all(hasattr(t, "region_spills") for t in logs)
        # per-tick deltas sum to the lifetime total the router reports
        assert sum(r["region_spills"] for r in rec.records) == \
            router.region_spills == rec.records[-1]["region_spills_total"]
    finally:
        router.close()
