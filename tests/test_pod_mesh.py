"""The multi-process pod plumbing that runs without spawning a pod:
mesh construction, the pod decode rules' collective-free guarantee, the
lockstep step digest, and the worker CLI's pod-flag validation."""
import json

import pytest
from jax.sharding import PartitionSpec as P

import jax
from repro.launch.mesh import (
    local_pod_mesh, make_pod_mesh, spmd_across_processes,
)
from repro.serving.worker import PodRuntime, step_digest
from repro.sharding import SERVE_RULES, pod_decode_rules, spec_for


# ------------------------------------------------------------------- meshes


def test_make_pod_mesh_axes_and_layout():
    mesh = make_pod_mesh()
    assert mesh.axis_names == ("data", "model")
    assert mesh.devices.shape == (1, len(jax.devices()))
    # explicit arrangement: the model axis is the raw device order (which
    # is process-major under jax.distributed — the axis that spans hosts)
    assert list(mesh.devices[0]) == list(jax.devices())


def test_make_pod_mesh_rejects_indivisible_data():
    with pytest.raises(ValueError):
        make_pod_mesh(data=len(jax.devices()) + 1)


def test_local_pod_mesh_covers_local_devices():
    mesh = local_pod_mesh()
    assert mesh.axis_names == ("model",)
    assert mesh.devices.size == len(jax.local_devices())


def test_spmd_probe_trivially_true_single_process():
    assert jax.process_count() == 1
    assert spmd_across_processes() is True


# ------------------------------------------------------- pod decode rules


def test_pod_decode_rules_batch_absorbs_every_mesh_axis():
    mesh = make_pod_mesh()
    rules = pod_decode_rules(mesh)
    assert rules.get("batch") == ("data", "model")
    # a KV-cache leaf: batch leads, so SERVE_RULES' model-axis mappings
    # (cache_seq here) are dropped by first-use-wins — the shard_map body
    # stays collective-free on ANY mesh
    kv = spec_for(("layers", "batch", "cache_seq", "kv_heads", None),
                  rules, mesh)
    assert kv == P(None, ("data", "model"))
    logits = spec_for(("batch", "seq", "vocab"), rules, mesh)
    assert logits == P(("data", "model"))
    # base table untouched for non-batch axes that DON'T collide
    assert SERVE_RULES.get("cache_seq") == ("model",)


def test_pod_decode_rules_on_classic_data_mesh_match_legacy():
    """On the single-host ("data",) mesh the derived specs are exactly the
    pre-pod hand-written ones — the generalization is a no-op there."""
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((len(jax.devices()),), ("data",))
    rules = pod_decode_rules(mesh)
    assert spec_for(("layers", "batch", "cache_seq", "kv_heads", None),
                    rules, mesh) == P(None, "data")
    assert spec_for(("batch", "seq"), rules, mesh) == P("data")
    assert spec_for(("batch",), rules, mesh) == P("data")


# ------------------------------------------------------------ step digest


def _reply(completed, queue_depth=0, active=0):
    return {"completed": completed, "queue_depth": queue_depth,
            "active": active}


def test_step_digest_order_independent_and_sensitive():
    a = _reply([{"rid": 1, "tokens_out": [5, 6]},
                {"rid": 2, "tokens_out": [7]}], 3, 1)
    b = _reply([{"rid": 2, "tokens_out": [7]},
                {"rid": 1, "tokens_out": [5, 6]}], 3, 1)
    assert step_digest(a) == step_digest(b)           # completion order: no
    assert step_digest(a) != step_digest(_reply(      # tokens: yes
        [{"rid": 1, "tokens_out": [5, 9]},
         {"rid": 2, "tokens_out": [7]}], 3, 1))
    assert step_digest(a) != step_digest(             # queue state: yes
        _reply(a["completed"], 2, 1))
    json.dumps(step_digest(a))                        # wire-safe


def test_step_digest_ignores_host_local_timestamps():
    base = [{"rid": 1, "tokens_out": [5], "t_done": 1.0}]
    other = [{"rid": 1, "tokens_out": [5], "t_done": 9.9}]
    assert step_digest(_reply(base)) == step_digest(_reply(other))


# ------------------------------------------------------------- worker CLI


def test_worker_cli_validates_pod_flags():
    from repro.serving.worker import main

    with pytest.raises(SystemExit):
        main(["--pod-rank", "0", "--pod-size", "2"])      # needs --listen
    with pytest.raises(SystemExit):
        main(["--listen", "127.0.0.1:0", "--pod-rank", "1"])   # no size
    with pytest.raises(SystemExit):
        main(["--listen", "127.0.0.1:0", "--pod-rank", "2",
              "--pod-size", "2"])                         # rank out of range
    with pytest.raises(SystemExit):
        main(["--listen", "127.0.0.1:0", "--pod-rank", "0",
              "--pod-size", "3", "--pod-peers", "127.0.0.1:1"])  # 1 != 2
    with pytest.raises(SystemExit):
        main(["--listen", "127.0.0.1:0", "--pod-rank", "1",
              "--pod-size", "2", "--pod-peers", "127.0.0.1:1"])  # head-only


def test_pod_runtime_roles():
    head = PodRuntime(0, 2, "127.0.0.1:9999", ("127.0.0.1:1",))
    rank = PodRuntime(1, 2, "127.0.0.1:9999")
    assert head.is_head and not rank.is_head
    assert head.info()["rank"] == 0 and rank.info()["size"] == 2
    assert head.info()["mode"] is None        # no engine built yet
