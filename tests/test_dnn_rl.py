"""Multi-stream DNN (paper §3.2): shapes, training convergence, permutation
feature importance; DQN allocator (§3.3.1): replay, target updates, learning
on a synthetic contextual task; feature engineering (§3.2.2).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dnn.features import (
    PERF_KEYS, RESOURCE_KEYS, RunningNorm, StreamBuilder, deploy_vector,
)
from repro.core.dnn.model import DNNConfig, MultiStreamDNN
from repro.core.dnn.train import (
    FEATURE_GROUPS, fit, permutation_importance, supervised_loss,
)
from repro.core.allocation.rl import ACTIONS, DQNAgent, DQNConfig

CFG = DNNConfig()


def synth_streams(rng, n):
    return {
        "resource": rng.standard_normal((n, CFG.window,
                                         CFG.n_resource_features)).astype(np.float32),
        "perf": rng.standard_normal((n, CFG.window,
                                     CFG.n_perf_features)).astype(np.float32),
        "deploy": rng.standard_normal((n, CFG.n_deploy_features)).astype(np.float32),
    }


def synth_dataset(rng, n=256):
    """Targets depend on the resource stream (channels 0-3) most, then perf —
    matching the paper's expected importance ordering.  The resource signal
    spans the whole window (the conv stream pools over time); the perf signal
    is recent (the GRU keys on the final hidden state)."""
    streams = synth_streams(rng, n)
    res_sig = streams["resource"][:, :, :4].mean(axis=(1, 2)) * np.sqrt(
        CFG.window * 4)
    perf_sig = streams["perf"][:, -4:, :4].mean(axis=(1, 2)) * np.sqrt(4 * 4)
    alloc = np.stack([res_sig * 2.0, res_sig + 0.3 * perf_sig,
                      0.8 * res_sig, res_sig - 0.3 * perf_sig],
                     1).astype(np.float32)
    strat = (res_sig > 0).astype(np.int32) * 2 + (perf_sig > 0).astype(np.int32)
    return {"streams": streams, "alloc_target": alloc,
            "strategy_target": strat}


def test_dnn_output_shapes():
    params, state = MultiStreamDNN.init(jax.random.PRNGKey(0), CFG)
    streams = {k: jnp.asarray(v) for k, v in
               synth_streams(np.random.default_rng(0), 3).items()}
    out, new_state = MultiStreamDNN.apply(params, state, streams, training=True)
    assert out["alloc"].shape == (3, CFG.n_resources)
    assert out["strategy_logits"].shape == (3, CFG.n_strategies)
    assert out["q"].shape == (3, CFG.n_actions)
    assert out["features"].shape == (3, CFG.feature_dim)
    # training=True updates BN stats, inference must not
    assert float(new_state["bn1"]["count"]) == 1.0
    _, st2 = MultiStreamDNN.apply(params, new_state, streams, training=False)
    assert float(st2["bn1"]["count"]) == 1.0


def test_dnn_fit_reduces_loss():
    rng = np.random.default_rng(1)
    ds = synth_dataset(rng, 256)
    params, state = MultiStreamDNN.init(jax.random.PRNGKey(1), CFG)
    params, state, losses = fit(params, state, ds, epochs=10, lr=3e-3,
                                batch_size=64)
    assert np.mean(losses[-4:]) < 0.4 * np.mean(losses[:4])


def test_permutation_importance_ranks_resource_first():
    rng = np.random.default_rng(2)
    ds = synth_dataset(rng, 384)
    params, state = MultiStreamDNN.init(jax.random.PRNGKey(2), CFG)
    params, state, _ = fit(params, state, ds, epochs=10, lr=3e-3)
    imp = permutation_importance(params, state, ds)
    assert set(imp) == set(FEATURE_GROUPS)
    assert abs(sum(imp.values()) - 1.0) < 1e-6
    assert imp["resource_utilization"] == max(imp.values())


# ---------------------------------------------------------------- features

def test_running_norm_standardizes():
    rn = RunningNorm(2)
    rng = np.random.default_rng(3)
    data = rng.normal([10.0, -5.0], [2.0, 0.5], size=(500, 2))
    for x in data:
        rn.update(x)
    z = np.stack([rn.normalize(x) for x in data])
    assert np.all(np.abs(z.mean(0)) < 0.1)
    assert np.all(np.abs(z.std(0) - 1.0) < 0.1)


def test_stream_builder_window_and_padding():
    sb = StreamBuilder(window=8)
    sb.push({k: 1.0 for k in RESOURCE_KEYS + PERF_KEYS})
    s = sb.streams(deploy_vector(model_params_b=7, family="dense",
                                 mesh_model=16, mesh_data=16, region_idx=0,
                                 slo_ms=200, cost_weight=0.5))
    assert s["resource"].shape == (1, 8, len(RESOURCE_KEYS))
    assert s["perf"].shape == (1, 8, len(PERF_KEYS))
    assert s["deploy"].shape == (1, 12)
    for _ in range(20):
        sb.push({k: 1.0 for k in RESOURCE_KEYS + PERF_KEYS})
    assert sb.streams(np.zeros(12, np.float32))["resource"].shape == \
        (1, 8, len(RESOURCE_KEYS))


def test_deploy_vector_one_hot_family():
    v = deploy_vector(model_params_b=7, family="moe", mesh_model=16,
                      mesh_data=16, region_idx=1, slo_ms=200, cost_weight=0.3)
    assert v.shape == (12,)
    assert v[6:].sum() == 1.0 and v[7] == 1.0      # moe is index 1


# ---------------------------------------------------------------- DQN

def test_dqn_epsilon_decays():
    agent = DQNAgent(CFG, DQNConfig(eps_decay_steps=100))
    assert agent.epsilon() == 1.0
    agent.step_count = 100
    assert agent.epsilon() == pytest.approx(0.05)


def test_dqn_learns_contextual_bandit():
    """Reward = +1 iff action matches the sign pattern of the resource stream;
    after training, greedy actions must beat random by a wide margin."""
    cfg = DQNConfig(warmup=64, train_every=1, eps_decay_steps=400,
                    batch_size=32, lr=1e-3)
    agent = DQNAgent(CFG, cfg, seed=3)
    rng = np.random.default_rng(4)

    def make_state():
        s = {k: np.zeros((1,) + v, np.float32) for k, v in {
            "resource": (CFG.window, CFG.n_resource_features),
            "perf": (CFG.window, CFG.n_perf_features),
            "deploy": (CFG.n_deploy_features,)}.items()}
        sign = rng.choice([-1.0, 1.0])
        s["resource"][:] = sign
        best = 6 if sign > 0 else 0          # +4 when high, -4 when low
        return s, best

    s, best = make_state()
    for _ in range(600):
        a = agent.act(s)
        r = 1.0 if a == best else -abs(a - best) / 6.0
        s2, best2 = make_state()
        agent.observe(s, a, r, s2)
        s, best = s2, best2
    correct = 0
    for _ in range(40):
        s, best = make_state()
        correct += agent.act(s, greedy=True) == best
    assert correct >= 30, f"greedy accuracy {correct}/40"


def test_replay_buffer_wraps():
    from repro.core.allocation.rl import ReplayBuffer
    shapes = {"resource": (4, 2), "perf": (4, 2), "deploy": (3,)}
    buf = ReplayBuffer(8, shapes)
    s = {k: np.zeros((1,) + v, np.float32) for k, v in shapes.items()}
    for i in range(20):
        buf.push(s, i % 7, float(i), s, False)
    assert buf.n == 8
    batch = buf.sample(np.random.default_rng(0), 4)
    assert batch[1].shape == (4,)
