"""Speculative decoding: the whole bit-equality contract.

Speculation is a pure latency optimization — exact-match acceptance means a
spec-on engine must emit byte-identical token streams to a plain engine for
EVERY sampling mode, pool layout, and model family (ineligible families
silently serve the plain path).  The suite pins that contract, the
rejected-tail rewind invariant (pool index == host positions after every
tick), the n-gram proposer's match-preference rules (longest-suffix-first,
newest-first, full-follow over truncated), the fused in-kernel sampler
against its jnp reference and the host sampler, the (seed, position)
stateless-sampling regression, and the StreamBuilder round-trip for the
acceptance/prefix-sharing metric channels.

A deterministic fuzz over ngram_propose always runs; hypothesis (when
installed) widens the same property.
"""
import functools

import numpy as np
import pytest

try:                       # degrade to the fixed grid, never to a dead module
    from hypothesis import given, settings, strategies as st
except ImportError:
    st = None

from repro.core.dnn.features import PERF_KEYS, RESOURCE_KEYS, StreamBuilder
from repro.kernels import ops, ref
from repro.serving import Request, SamplingParams, ServingEngine, sample_token
from repro.serving.draft import ngram_propose
from repro.serving.engine import EngineCore

from conftest import TINY_CFGS

MAX_SEQ = 32
# rewindable full-ring caches — the eligibility gate lets these speculate
SPEC_FAMILIES = ["dense", "vlm", "moe"]
# sliding-window rings wrap, SSM/hybrid recurrence can't roll back
GATED_FAMILIES = ["swa", "ssm2", "hybrid"]


@functools.lru_cache(maxsize=None)
def core_for(family: str) -> EngineCore:
    return EngineCore(TINY_CFGS[family], MAX_SEQ, seed=0)


def make_engine(family: str, *, spec_k=0, slots=2, pool="dense",
                **kw) -> ServingEngine:
    core = core_for(family)
    if pool == "paged":
        kw.update(pool="paged", block_size=4,
                  num_blocks=slots * (MAX_SEQ // 4) + 1)
    return ServingEngine(core.cfg, slots=slots, max_seq=MAX_SEQ, core=core,
                         spec_k=spec_k, **kw)


def echo_requests(family: str, n, *, prompt_len=12, gen_len=10, period=4,
                  seed=0, sampling=None):
    """Prompts that tile a short random phrase — the workload prompt lookup
    is built for, so drafts actually fire."""
    cfg = TINY_CFGS[family]
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        phrase = rng.integers(3, cfg.vocab, size=period)
        prompt = np.tile(phrase, prompt_len // period + 1)[:prompt_len]
        reqs.append(Request(rid=i, prompt=prompt.astype(np.int32),
                            gen_len=gen_len,
                            sampling=sampling or SamplingParams()))
    return reqs


def run_to_completion(eng, n, max_steps=500):
    done, now = [], 0.0
    for _ in range(max_steps):
        now += 1.0
        done.extend(eng.step(now=now))
        if len(done) >= n and eng.idle:
            return {r.rid: r.tokens_out for r in done}
    raise AssertionError(f"only {len(done)}/{n} completed")


def run_pair(family, reqs_fn, *, spec_k=3, pool="dense", **kw):
    plain = make_engine(family, spec_k=0, **kw)
    spec = make_engine(family, spec_k=spec_k, pool=pool, **kw)
    n = None
    for eng in (plain, spec):
        reqs = reqs_fn()
        n = len(reqs)
        for r in reqs:
            eng.submit(r, now=0.0)
    return run_to_completion(plain, n), run_to_completion(spec, n), spec


# ------------------------------------------------- spec-vs-plain bit equality


@pytest.mark.parametrize("family", SPEC_FAMILIES)
def test_spec_matches_plain_greedy(family):
    """Greedy streams must be bit-identical with speculation on — and the
    spec engine must actually have speculated (the workload is draftable),
    else the equality is vacuous."""
    want, got, spec = run_pair(family, lambda: echo_requests(family, 3))
    assert got == want
    assert spec.stats.total_spec_proposed > 0
    assert 0 <= spec.stats.total_spec_accepted \
        <= spec.stats.total_spec_proposed


@pytest.mark.parametrize("family", GATED_FAMILIES)
def test_ineligible_families_silently_serve_plain(family):
    """spec_k on a non-rewindable cache is a no-op knob, never an error:
    the gate disables speculation and the stream is the plain stream."""
    want, got, spec = run_pair(family, lambda: echo_requests(family, 2))
    assert not spec._spec_ok
    assert got == want
    assert spec.stats.total_spec_proposed == 0


def test_spec_matches_plain_temperature():
    """Exact-match acceptance is sampling-mode agnostic: seeded temperature
    rows accept a draft token iff the host sample equals it, so the stream
    stays identical to plain decode."""
    sampling = SamplingParams(temperature=0.8, top_k=8, seed=11)
    want, got, spec = run_pair(
        "dense", lambda: echo_requests("dense", 2, sampling=sampling))
    assert got == want


def test_spec_matches_plain_paged_pool():
    """Paged block tables rewind through the same index-vector contract as
    dense rings — paged spec-on == dense plain, token for token."""
    want, got, spec = run_pair("dense", lambda: echo_requests("dense", 3),
                               pool="paged")
    assert got == want
    assert spec.stats.total_spec_proposed > 0


def test_greedy_decode_pulls_no_host_logits():
    """The fused in-kernel sampler keeps greedy ticks devicebound: a plain
    greedy run materializes ZERO host logits rows; a temperature run pulls
    (host sampling is the contract there)."""
    eng = make_engine("dense", slots=2)
    for r in echo_requests("dense", 2):
        eng.submit(r, now=0.0)
    run_to_completion(eng, 2)
    assert eng.logits_pulls == 0
    hot = make_engine("dense", slots=2)
    for r in echo_requests("dense", 2,
                           sampling=SamplingParams(temperature=0.9, seed=1)):
        hot.submit(r, now=0.0)
    run_to_completion(hot, 2)
    assert hot.logits_pulls > 0


# ------------------------------------------------------- rejected-tail rewind


def test_rewind_restores_pool_index_every_tick():
    """After EVERY tick the pool index vector must equal the host position
    vector for active rows — rejected (and unconsumed) speculative writes
    sit past the index and get re-covered by later writes.  The run must
    contain at least one rejection, else the invariant is untested."""
    eng = make_engine("dense", slots=2, spec_k=3)
    for r in echo_requests("dense", 2, gen_len=12, seed=3):
        eng.submit(r, now=0.0)
    now, done = 0.0, []
    for _ in range(200):
        now += 1.0
        done.extend(eng.step(now=now))
        active = np.nonzero(eng.active)[0]
        np.testing.assert_array_equal(
            np.asarray(eng.pool.index)[active], eng.pos[active])
        if len(done) >= 2 and eng.idle:
            break
    assert len(done) == 2
    st_ = eng.stats
    assert st_.total_spec_proposed > st_.total_spec_accepted  # saw rejects


def test_rewound_cache_rows_match_plain_engine():
    """The valid cache region [0, pos) of a spec engine must equal the plain
    engine's after identical traffic — speculation may only leave garbage at
    rows the index has been rewound past."""
    engines = {}
    for spec_k in (0, 3):
        eng = make_engine("dense", slots=1, spec_k=spec_k)
        [r] = echo_requests("dense", 1, gen_len=8, seed=5)
        eng.submit(r, now=0.0)
        run_to_completion(eng, 1)
        engines[spec_k] = eng
    k0 = np.asarray(engines[0].pool.cache["layers"]["k"], np.float32)
    k3 = np.asarray(engines[3].pool.cache["layers"]["k"], np.float32)
    pos = int(engines[0].pool.index[0])
    assert int(engines[3].pool.index[0]) == pos
    # k layout: (layers, slots, Smax, KV, hd) — slice the position axis
    np.testing.assert_allclose(k3[:, :, :pos], k0[:, :, :pos], atol=1e-6)
    # and the garbage really is past the index (the diff exists at all)
    assert np.abs(k3[:, :, pos:] - k0[:, :, pos:]).max() > 0.0


# ------------------------------------------------------------- ngram_propose


def test_ngram_empty_cases():
    assert ngram_propose([1, 2, 3], k=0).size == 0
    assert ngram_propose([7], k=3).size == 0
    assert ngram_propose([], k=3).size == 0
    # all-unique history: no earlier occurrence of any suffix n-gram
    assert ngram_propose(list(range(10)), k=3).size == 0


def test_ngram_longest_suffix_wins():
    # order-3 match exists (follow [5,1,2]); order-1 [3] also matches at
    # i=1 (follow 9) — the more specific match must win
    h = [7, 3, 9, 1, 2, 3, 5, 1, 2, 3]
    assert ngram_propose(h, k=3, ngram=3).tolist() == [5, 1, 2]


def test_ngram_newest_match_wins_within_order():
    # [1,2] occurs twice with full follows; the newer occurrence (follow 6)
    # must win — recency tracks local context
    h = [1, 2, 5, 1, 2, 6, 1, 2]
    assert ngram_propose(h, k=1, ngram=2).tolist() == [6]


def test_ngram_prefers_full_follow_over_truncated():
    # period-2 cycle: the newest [2,1,2] match (i=3) has only a 2-token
    # follow; one cycle earlier (i=1) the same continuation is available at
    # full length — the full follow must win, not the newer truncated one
    h = [1, 2, 1, 2, 1, 2, 1, 2]
    assert ngram_propose(h, k=3, ngram=3).tolist() == [1, 2, 1]


def test_ngram_truncated_fallback_when_no_full_follow():
    # the only match sits too close to the end for k=4 — the truncated
    # follow is still proposed (a short draft beats no draft)
    h = [9, 8, 1, 2, 3, 1, 2, 3]
    assert ngram_propose(h, k=4, ngram=3).tolist() == [1, 2, 3]


def test_ngram_list_and_array_inputs_agree():
    h = [1, 2, 1, 2, 1, 2]
    a = ngram_propose(h, k=2, ngram=2)
    b = ngram_propose(np.asarray(h, np.int32), k=2, ngram=2)
    assert a.dtype == np.int32 and a.tolist() == b.tolist()


def _check_proposal_is_valid_continuation(h, k, ngram):
    d = ngram_propose(h, k=k, ngram=ngram)
    assert 0 <= d.size <= max(k, 0)
    if d.size == 0:
        return
    T = len(h)
    follow = d.tolist()
    ok = False
    for n in range(1, min(ngram, T - 1) + 1):
        tail = h[T - n:]
        for i in range(T - n):
            if h[i:i + n] == tail and h[i + n:i + n + len(follow)] == follow:
                ok = True
    assert ok, f"proposal {follow} is not the follow of any suffix match"


def test_ngram_fuzz_deterministic():
    rng = np.random.default_rng(0)
    for _ in range(300):
        T = int(rng.integers(0, 40))
        h = rng.integers(0, int(rng.integers(2, 8)), size=T).tolist()
        _check_proposal_is_valid_continuation(
            h, int(rng.integers(0, 6)), int(rng.integers(1, 5)))


if st is not None:
    @settings(max_examples=200, deadline=None)
    @given(h=st.lists(st.integers(0, 5), max_size=48),
           k=st.integers(0, 6), ngram=st.integers(1, 5))
    def test_ngram_fuzz_hypothesis(h, k, ngram):
        _check_proposal_is_valid_continuation(h, k, ngram)


# ------------------------------------------------- sampling: host and fused


def test_sample_token_stateless_fallback_advances_with_position():
    """Regression: the rng-less fallback seeds from (seed, position).
    Seeding from ``seed`` alone rebuilt the identical generator every call
    and emitted the same token forever."""
    params = SamplingParams(temperature=1.0, seed=3)
    logits = np.zeros(32)                       # uniform — pure randomness
    draws = [sample_token(logits, params, position=p) for p in range(12)]
    assert len(set(draws)) > 1                  # positions advance the stream
    again = [sample_token(logits, params, position=p) for p in range(12)]
    assert draws == again                       # and it's reproducible


@pytest.mark.kernels
def test_fused_sample_kernel_matches_ref_bitwise():
    """The Pallas sampler and the independently-written jnp reference must
    agree BITWISE on mixed greedy/temperature rows; greedy rows must equal
    the host sampler's f32 argmax (the engine relies on this to fuse greedy
    ticks without changing streams)."""
    rng = np.random.default_rng(7)
    B, V = 6, 64
    logits = rng.standard_normal((B, V)).astype(np.float32)
    seed = np.arange(B, dtype=np.int32)
    rid = (np.arange(B, dtype=np.int32) * 13) % 7
    pos = np.arange(B, dtype=np.int32) + 2
    temp = np.array([0.0, 0.7, 0.0, 1.3, 0.05, 0.0], np.float32)
    got = np.asarray(ops.fused_sample(logits, seed, rid, pos, temp,
                                      interpret=True))
    want = np.asarray(ref.fused_sample_ref(logits, seed, rid, pos, temp))
    np.testing.assert_array_equal(got, want)
    for b in np.nonzero(temp == 0.0)[0]:
        assert got[b] == sample_token(logits[b], SamplingParams())


# ------------------------------------------- metric channels / StreamBuilder


def test_stream_builder_round_trips_spec_and_prefix_channels():
    """The acceptance-rate and prefix-sharing channels must occupy stable
    columns in the DNN input streams: push a record with distinct values
    per key and pin each one to its column, then check the stream shapes
    the model was sized for."""
    assert "prefix_hits" in RESOURCE_KEYS and "tokens_shared" in RESOURCE_KEYS
    assert "accept_rate" in PERF_KEYS
    sb = StreamBuilder(window=4)
    rec = {k: float(i + 1) for i, k in enumerate(RESOURCE_KEYS)}
    rec.update({k: float(100 + i) for i, k in enumerate(PERF_KEYS)})
    sb.push(rec)
    assert sb.res_hist[-1].tolist() == [float(i + 1)
                                        for i in range(len(RESOURCE_KEYS))]
    assert sb.perf_hist[-1].tolist() == [float(100 + i)
                                         for i in range(len(PERF_KEYS))]
    # missing keys (e.g. dense fleets report no prefix stats) default to 0
    sb.push({"flop_util": 0.5})
    assert sb.res_hist[-1][RESOURCE_KEYS.index("prefix_hits")] == 0.0
    streams = sb.streams(np.zeros(12, np.float32))
    assert streams["resource"].shape == (1, 4, len(RESOURCE_KEYS))
    assert streams["perf"].shape == (1, 4, len(PERF_KEYS))


def test_engine_lifetime_reports_spec_counters():
    eng = make_engine("dense", slots=2, spec_k=3)
    for r in echo_requests("dense", 2):
        eng.submit(r, now=0.0)
    run_to_completion(eng, 2)
    life = eng.lifetime()
    assert life["spec_proposed"] == eng.stats.total_spec_proposed > 0
    assert 0 <= life["spec_accepted"] <= life["spec_proposed"]
    assert life["logits_pulls"] == 0            # greedy run stayed fused
