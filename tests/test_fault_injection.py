"""Adversarial transport harness: every injected fault must surface as a
typed TransportError / reaped replica — never a hang, never a stranded or
double-served request — while benign faults (splits, delays) leave the TCP
topology observationally identical to in-process serving.

Faults are injected through repro.serving.chaos: a byte-level proxy between
a real TcpReplica stub and a real worker subprocess (splits / delays /
mid-frame severs / duplicated frames at chosen frame indices), plus plain
sockets for handshake-deadline scenarios.
"""
import socket
import threading

import numpy as np
import pytest

from repro.core.monitoring.collector import MetricsCollector
from repro.serving import (
    InProcessReplica, ReplicaRouter, Request, TcpReplica, spawn_worker,
)
from repro.serving.chaos import ChaosProxy, FaultPlan, FaultyConnection
from repro.serving.transport import Connection, Listener, TransportError

from conftest import TINY_CFGS

CFG = TINY_CFGS["dense"]
SLOTS, MAX_SEQ = 2, 24


def _requests(n, gen_len=3, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(
                3, CFG.vocab, size=5).astype(np.int32),
                gen_len=gen_len) for i in range(n)]


def _drive(rep, reqs, max_now=100):
    done, now = [], 0.0
    for r in reqs:
        rep.submit(r, now=0.0)
    while len(done) < len(reqs) and now < max_now:
        now += 1.0
        done.extend(rep.step(now))
    return {r.rid: tuple(r.tokens_out) for r in done}


@pytest.fixture
def tcp_worker():
    addr, proc = spawn_worker(once=True)
    yield addr
    if proc.poll() is None:
        proc.kill()
    proc.wait(timeout=30)


# ----------------------------------------------------- benign faults absorb


@pytest.mark.slow
def test_split_and_delayed_frames_are_observationally_identical(tcp_worker):
    """Frames chopped to 7-byte pieces with per-piece delays on BOTH
    directions: the framing reassembles everything, so the TCP replica's
    token streams equal the in-process replica's bit-for-bit."""
    want = _drive(InProcessReplica.build(CFG, slots=SLOTS, max_seq=MAX_SEQ,
                                         prefill_chunk=4), _requests(3))
    plan = FaultPlan(chunk_bytes=7, delay_s=0.0005)
    with ChaosProxy(tcp_worker, c2s=plan, s2c=plan) as proxy:
        rep = TcpReplica(CFG, slots=SLOTS, max_seq=MAX_SEQ, prefill_chunk=4,
                         addr=proxy.addr)
        try:
            got = _drive(rep, _requests(3))
        finally:
            rep.close()
    assert got == want and not rep.failed


# -------------------------------------------------- hard faults surface typed


@pytest.mark.slow
def test_sever_mid_frame_reaps_replica_and_recovers_requests(tcp_worker):
    """The worker's FIRST step reply is cut in half (frame 3 server→client;
    frames 1–2 were the attach and init acks).  The stub must see a typed
    failure — not a hang — flip failed, emit a crash report, and hand back
    rewound requests for requeue."""
    with ChaosProxy(tcp_worker, s2c=FaultPlan(sever_in_frame=3)) as proxy:
        rep = TcpReplica(CFG, slots=SLOTS, max_seq=MAX_SEQ, prefill_chunk=4,
                         addr=proxy.addr, replica_id=9, rpc_timeout_s=60.0)
        try:
            reqs = _requests(2)
            for r in reqs:
                rep.submit(r, now=0.0)
            out = rep.step(1.0)            # reply severed mid-frame
            assert out == [] and rep.failed
            report = rep.report(tick=0)
            assert report.n_errors > 0 and report.replica_id == 9
            collector = MetricsCollector()
            collector.submit(report)
            assert 9 in collector.stragglers()
            lost = rep.lost_requests()
            assert sorted(r.rid for r in lost) == [0, 1]
            assert all(r.tokens_out == [] and r.t_admit is None
                       for r in lost)
        finally:
            rep.close()


@pytest.mark.slow
def test_duplicated_reply_frame_retires_replica_never_mismatches(tcp_worker):
    """A duplicated step reply through the proxy: the stub must fail TYPED
    on a later op (the buffered duplicate desyncs the stream — or, if the
    teardown races it, the dead channel EOFs), flip failed, emit a crash
    report, and recover the submitter's requests.  What it must NEVER do
    is hand a stale reply to the wrong call or hang."""
    with ChaosProxy(tcp_worker, s2c=FaultPlan(duplicate_frame=3)) as proxy:
        rep = TcpReplica(CFG, slots=SLOTS, max_seq=MAX_SEQ, prefill_chunk=4,
                         addr=proxy.addr, replica_id=3, rpc_timeout_s=60.0)
        try:
            [req] = _requests(1)
            rep.submit(req, now=0.0)
            rep.step(1.0)                  # reply #3 arrives twice
            with pytest.raises(TransportError):
                rep._rpc({"op": "report"})
            assert rep.failed
            assert rep.report(tick=1).n_errors > 0
            assert [r.rid for r in rep.lost_requests()] == [0]
        finally:
            rep.close()


class _ScriptedWorker:
    """A protocol-speaking fake worker (no engine, no subprocess): answers
    every op with a minimal well-formed reply, echoing seq — and replays
    the step reply when told to.  Lets the desync tests be deterministic
    at any machine load."""

    def __init__(self, *, duplicate_step_reply: bool = False):
        self.listener = Listener("127.0.0.1", 0)
        self.duplicate_step_reply = duplicate_step_reply
        self.thread = threading.Thread(target=self._serve, daemon=True)
        self.thread.start()

    @property
    def addr(self):
        return self.listener.addr

    def _serve(self):
        try:
            conn = self.listener.accept(timeout=30, conn_timeout=30)
            while True:
                msg = conn.recv()
                op = msg.get("op")
                if op == "step":
                    reply = {"completed": [], "queue_depth": 0, "active": 0,
                             "slot_utilization": 0.0}
                elif op == "report":
                    reply = {"window": {"latency_ms_samples": [],
                                        "n_requests": 0, "n_tokens": 0,
                                        "slot_util": 0.0, "queue_depth": 0}}
                else:
                    reply = {"ok": True}
                reply["seq"] = msg.get("seq")
                conn.send(reply)
                if op == "step" and self.duplicate_step_reply:
                    conn.send(reply)       # the injected twin
                if op == "shutdown":
                    return
        except TransportError:
            return

    def close(self):
        self.listener.close()
        self.thread.join(timeout=10)


def test_duplicated_reply_is_a_seq_desync_not_a_silent_mismatch():
    """The exact protocol property the seq echo buys: a duplicated step
    reply is syntactically valid JSON, so without the seq check the next
    RPC would silently consume the previous op's reply.  Against a
    scripted worker (no timing, no teardown races) the desync is the
    guaranteed outcome."""
    worker = _ScriptedWorker(duplicate_step_reply=True)
    rep = TcpReplica(CFG, slots=SLOTS, max_seq=MAX_SEQ, addr=worker.addr,
                     replica_id=5, rpc_timeout_s=30.0)
    try:
        rep.step(1.0)                      # reply arrives twice
        with pytest.raises(TransportError, match="desync"):
            rep._rpc({"op": "report"})
        assert rep.failed
    finally:
        rep.close()
        worker.close()


@pytest.mark.slow
def test_corrupted_reply_payload_is_typed_error(tcp_worker):
    """One flipped byte inside the attach reply payload (the handshake's
    first server frame) → malformed JSON → TransportError from the
    constructor, never a hang."""
    with ChaosProxy(tcp_worker, s2c=FaultPlan(corrupt_in_frame=1)) as proxy:
        with pytest.raises(TransportError):
            TcpReplica(CFG, slots=SLOTS, max_seq=MAX_SEQ, prefill_chunk=4,
                       addr=proxy.addr, rpc_timeout_s=60.0)


def test_delayed_handshake_hits_the_init_deadline():
    """A peer that accepts the TCP connect but never answers the init
    handshake must bounce the constructor within init_timeout_s."""
    lst = Listener("127.0.0.1", 0)
    stop = threading.Event()

    def black_hole():
        sock = lst.accept(timeout=30).sock     # connect succeeds...
        stop.wait(10)                          # ...but no reply ever comes
        sock.close()

    t = threading.Thread(target=black_hole, daemon=True)
    t.start()
    with pytest.raises(TransportError):
        TcpReplica(CFG, slots=SLOTS, max_seq=MAX_SEQ, addr=lst.addr,
                   init_timeout_s=1.0)
    stop.set()
    t.join(timeout=10)
    lst.close()


def test_connect_deadline_surfaces_refused_peer():
    lst = Listener("127.0.0.1", 0)
    addr = lst.addr
    lst.close()
    with pytest.raises(TransportError):
        TcpReplica(CFG, slots=SLOTS, max_seq=MAX_SEQ, addr=addr,
                   connect_timeout_s=2.0)


def test_faulty_connection_sever_is_mid_frame_eof_for_the_peer():
    """Endpooint-level shim: a send severed at half-frame leaves the peer
    reading a truncated frame → TransportError, and the sender gets the
    typed error immediately."""
    a_sock, b_sock = socket.socketpair()
    a = FaultyConnection(a_sock, FaultPlan(sever_in_frame=2), timeout=10.0)
    b = Connection(b_sock, timeout=10.0)
    a.send({"fine": 1})
    assert b.recv() == {"fine": 1}
    with pytest.raises(TransportError):
        a.send({"doomed": list(range(32))})
    with pytest.raises(TransportError):
        b.recv()
    b.close()


def test_faulty_connection_duplicate_and_split_reassemble():
    a_sock, b_sock = socket.socketpair()
    a = FaultyConnection(a_sock, FaultPlan(chunk_bytes=3, duplicate_frame=1),
                         timeout=10.0)
    b = Connection(b_sock, timeout=10.0)
    a.send({"msg": "dup"})
    assert b.recv() == {"msg": "dup"}      # the frame...
    assert b.recv() == {"msg": "dup"}      # ...and its injected twin
    a.close(), b.close()


# ------------------------------------------------- fleet-level fault closure


@pytest.mark.slow
def test_tcp_worker_kill_mid_decode_completes_every_request_exactly_once():
    """Kill one TCP worker mid-decode: the router reaps it on the next
    step, requeues its rewound requests, builds a replacement, and every
    request completes exactly once."""
    router = ReplicaRouter.from_topology(CFG, "tcp", slots=SLOTS,
                                         max_seq=16, prefill_chunk=4,
                                         n_replicas=2, max_replicas=3)
    try:
        reqs = _requests(6, gen_len=6)
        for r in reqs:
            router.submit(r, now=0.0)
        done, now = [], 0.0
        while len(done) < 2 and now < 100:   # victim serves real work first
            now += 1.0
            done.extend(router.step(now))
        victim = router.replicas[1]
        assert isinstance(victim, TcpReplica)
        victim._proc.kill()
        victim._proc.wait(timeout=30)
        while len(done) < 6 and now < 200:
            now += 1.0
            done.extend(router.step(now))
        rids = sorted(r.rid for r in done)
        assert rids == list(range(6))        # exactly once, none lost
        assert all(len(r.tokens_out) == 6 for r in done)
        assert router.replica_count == 2
        assert router.metrics()["completed"] == 6
    finally:
        router.close()
