"""Shared fixtures: tiny per-family model configs (CPU-fast).

IMPORTANT: tests must see the default single CPU device — the 512-device
XLA override belongs exclusively to launch/dryrun.py (and the subprocess
sharding tests, which re-exec python with their own env).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import LM, ModelConfig, MoECfg, SSMCfg, HybridCfg

B, S, V = 2, 16, 64


def tiny(family, **kw):
    base = dict(name=f"tiny-{family}", family=family, n_layers=2, d_model=32,
                n_heads=4, n_kv_heads=2, d_ff=64, vocab=V,
                param_dtype="float32", dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


TINY_CFGS = {
    "dense": tiny("dense", qkv_bias=True),
    "swa": tiny("dense", sliding_window=8),
    "vlm": tiny("vlm", m_rope=True, m_rope_sections=(2, 1, 1), n_vision_patches=4),
    # capacity_factor=4.0 ⇒ dropless at this size (decode consistency exact)
    "moe": tiny("moe", moe=MoECfg(n_experts=4, top_k=2, d_ff_expert=32,
                                  capacity_factor=4.0)),
    "ssm1": tiny("ssm", n_heads=0, n_kv_heads=0, d_ff=0,
                 ssm=SSMCfg(d_state=4, version=1)),
    "ssm2": tiny("ssm", n_heads=0, n_kv_heads=0, d_ff=0,
                 ssm=SSMCfg(d_state=4, version=2, headdim=8)),
    "hybrid": tiny("hybrid", n_heads=4, n_kv_heads=4, d_ff=64,
                   ssm=SSMCfg(d_state=4, version=2, headdim=8),
                   hybrid=HybridCfg(attn_every=2, n_shared_blocks=2)),
    "audio": tiny("audio", enc_dec=True, n_enc_layers=2),
}


def inputs_for(cfg, key, batch=B, seq=S):
    out = {"tokens": jax.random.randint(key, (batch, seq), 0, cfg.vocab)}
    if cfg.family == "vlm":
        out["patches"] = jnp.ones((batch, cfg.n_vision_patches, cfg.d_model),
                                  jnp.float32)
    if cfg.enc_dec:
        out["frames"] = jnp.ones((batch, seq, cfg.d_model), jnp.float32)
    return out


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(params=list(TINY_CFGS))
def family_cfg(request):
    return request.param, TINY_CFGS[request.param]
