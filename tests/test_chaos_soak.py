"""Seeded chaos soak (the ``chaos`` marker): a mixed proc+TCP fleet under
the closed-loop wiring with scripted worker kills and straggler injections.
The invariant under ALL of it: every admitted request completes exactly
once, the fleet's lifetime counters balance against what the driver
collected, and every fault shows up in the collector — crashes as
straggler flags, evictions as actuated replacements.
"""
from collections import Counter

import numpy as np
import pytest

from repro.core.monitoring.collector import MetricsCollector, ReplicaReport
from repro.core.scaling.scaler import EvictionPolicy
from repro.serving import ProcessReplica, ReplicaRouter, Request, TcpReplica

from conftest import TINY_CFGS

CFG = TINY_CFGS["dense"]
SLOTS, MAX_SEQ, GEN_LEN = 2, 16, 4
N_REQUESTS = 14
KILL_TICKS = (4, 9)            # scripted worker kills (any live victim)
STRAGGLE_TICKS = (6, 7)        # injected straggler windows (K=2 → evict)


def _lat_report(rid, tick, lat_ms):
    return ReplicaReport(replica_id=rid, tick=tick,
                         latency_ms_samples=[lat_ms] * 4, n_requests=4,
                         n_errors=0, flop_util=0.5, hbm_util=0.5,
                         ici_util=0.0, mem_frac=0.5, queue_depth=0)


@pytest.mark.chaos
@pytest.mark.slow
def test_chaos_soak_mixed_fleet_exactly_once_and_counters_balance():
    def factory(rid):
        cls = ProcessReplica if rid % 2 == 0 else TcpReplica
        return cls(CFG, slots=SLOTS, max_seq=MAX_SEQ, prefill_chunk=4,
                   replica_id=rid)

    router = ReplicaRouter(factory, n_replicas=3, max_replicas=4)
    collector = MetricsCollector(straggler_factor=1.5)
    policy = EvictionPolicy(k_windows=2)
    rng = np.random.default_rng(42)
    reqs = [Request(rid=i, prompt=rng.integers(
                3, CFG.vocab, size=5).astype(np.int32), gen_len=GEN_LEN)
            for i in range(N_REQUESTS)]

    done, killed, evicted_ids = [], [], []
    flagged_ever: set[int] = set()
    submitted, now, tick = 0, 0.0, 0
    try:
        while (len(done) < N_REQUESTS or submitted < N_REQUESTS) \
                and tick < 120:
            tick += 1
            now += 1.0
            for _ in range(2):                     # staggered admissions
                if submitted < N_REQUESTS:
                    router.submit(reqs[submitted], now=now)
                    submitted += 1
            if tick in KILL_TICKS:                 # scripted chaos: SIGKILL
                victim = router.replicas[-1]
                killed.append(victim.replica_id)
                victim._proc.kill()
                victim._proc.wait(timeout=30)
            done.extend(router.step(now))
            for rep in router.reports(tick):
                collector.submit(rep)
                if rep.n_errors > 0:    # recorded NOW — aggregate() prunes
                    flagged_ever.add(rep.replica_id)
            if tick in STRAGGLE_TICKS:
                # scripted straggler: one live replica "goes slow" (injected
                # latency evidence), the rest stay at baseline
                live = sorted(r.replica_id for r in router.serving_replicas)
                slow, rest = live[0], live[1:]
                collector.submit(_lat_report(slow, tick, 5000.0))
                for rid in rest:
                    collector.submit(_lat_report(rid, tick, 100.0))
            evicted_ids.extend(router.evict_stragglers(
                policy.update(collector.stragglers(),
                              router.replica_count), now=now))
            collector.aggregate(tick, n_replicas=router.replica_count,
                                max_replicas=4)

        # drain ticks: age every retired replica past max_staleness so the
        # footprint assertions below observe the pruned steady state
        for _ in range(collector.max_staleness + 1):
            tick += 1
            collector.aggregate(tick, n_replicas=router.replica_count,
                                max_replicas=4)

        # every admitted request completed EXACTLY once, fully generated
        counts = Counter(r.rid for r in done)
        assert sorted(counts) == list(range(N_REQUESTS))
        assert all(c == 1 for c in counts.values()), counts
        assert all(len(r.tokens_out) == GEN_LEN for r in done)

        # the chaos actually happened: both kills landed, and the injected
        # straggler was evicted by the K-consecutive-windows policy
        assert len(killed) == 2
        assert len(evicted_ids) >= 1
        assert not set(evicted_ids) & set(killed)  # evicted ≠ crash-reaped

        # fleet lifetime counters balance against the driver's collection
        m = router.metrics()
        assert m["completed"] == N_REQUESTS
        assert m["completed_tokens"] == sum(len(r.tokens_out) for r in done)
        assert m["replicas"] == 3                  # kills + evictions were
        #                                            replaced, not absorbed

        # and the control plane SAW the faults: each killed replica's crash
        # report reached the collector as a straggler flag at some point
        assert set(killed) <= flagged_ever

        # retired replicas aged out of the collector entirely — reports,
        # error flags, latency EWMAs: a 120-tick soak's collector footprint
        # is bounded by the LIVE fleet, not the whole churn history
        retired = set(killed) | set(evicted_ids)
        assert retired and not retired & set(collector.reports)
        assert not retired & set(collector._errored)
        assert not retired & set(collector._lat_ewma)
        assert len(collector.reports) <= router.replica_count + 1
    finally:
        router.close()


@pytest.mark.chaos
@pytest.mark.slow
def test_preemption_storm_under_price_spike_exactly_once_and_drains():
    """Seeded preemption storm on a geographic spot fleet: the market is
    forced into an immediate spike (spike_prob=1), and every tick the spot
    price exceeds the on-demand rate one preemptible replica is reclaimed
    without notice — the provider pulling capacity exactly when it gets
    expensive.  Invariants: every admitted request still completes exactly
    once (rewind + requeue through the survivors), the router's lifetime
    preemption counter equals the scripted reclaims, the reclaimed ids
    reach the collector's per-tick ``preemptions`` channel via
    observe_fleet, and after the storm the collector's footprint drains to
    the surviving fleet."""
    from repro.serving import InProcessReplica, ServingEngine
    from repro.serving.engine import EngineCore
    from repro.serving.profiles import FleetPlan, SpotMarket

    core = EngineCore(CFG, MAX_SEQ, seed=0)

    def factory(rid):
        return InProcessReplica(ServingEngine(
            CFG, slots=SLOTS, max_seq=MAX_SEQ, prefill_chunk=4, core=core,
            replica_id=rid))

    market = SpotMarket(seed=11, spike_prob=1.0)     # storm from tick 1
    plan = FleetPlan(reserved=1, regions=("na", "apac"), market=market)
    router = ReplicaRouter(factory, n_replicas=4, max_replicas=4,
                           profile_fn=plan)
    collector = MetricsCollector()
    rng = np.random.default_rng(7)
    n_requests = 16
    reqs = [Request(rid=i, prompt=rng.integers(
                3, CFG.vocab, size=5).astype(np.int32), gen_len=GEN_LEN,
                tier="batch" if i % 3 == 0 else "interactive")
            for i in range(n_requests)]

    done, reclaimed, spike_ticks = [], [], []
    per_tick_preemptions = []
    submitted, now, tick = 0, 0.0, 0
    try:
        while (len(done) < n_requests or submitted < n_requests) \
                and tick < 120:
            tick += 1
            now += 1.0
            for _ in range(2):
                if submitted < n_requests:
                    router.submit(reqs[submitted], now=now)
                    submitted += 1
            price = market.price(tick)
            if price > plan.cost_on_demand:          # the reclaim trigger
                spike_ticks.append(tick)
                spots = [r for r in router.serving_replicas
                         if plan(r.replica_id).preemptible]
                if len(spots) > 1 or (spots and len(
                        router.serving_replicas) > 1):
                    victim = spots[-1].replica_id
                    if router.preempt(victim, now=now):
                        reclaimed.append(victim)
            done.extend(router.step(now))
            for rep in router.reports(tick):
                collector.submit(rep)
            router_m = router.metrics()
            collector.observe_fleet({
                "preemptions": router_m["preemptions"],
                "tier_spills": router_m["tier_spills"],
                "region_spills": router_m["region_spills"]})
            rec = collector.aggregate(tick, n_replicas=router.replica_count,
                                      max_replicas=4)
            per_tick_preemptions.append(rec["preemptions"])

        for _ in range(collector.max_staleness + 1):  # drain ticks
            tick += 1
            collector.aggregate(tick, n_replicas=router.replica_count,
                                max_replicas=4)

        # the storm actually happened and capacity was NOT replaced
        assert spike_ticks and reclaimed
        assert router.replica_count == 4 - len(reclaimed)

        # exactly once, fully generated, across rewind + requeue
        counts = Counter(r.rid for r in done)
        assert sorted(counts) == list(range(n_requests))
        assert all(c == 1 for c in counts.values()), counts
        assert all(len(r.tokens_out) == GEN_LEN for r in done)

        # lifetime counters balance, and the per-tick channel integrates
        # back to the lifetime total (deltas, not stale repeats)
        m = router.metrics()
        assert m["completed"] == n_requests
        assert m["preemptions"] == len(reclaimed)
        assert sum(per_tick_preemptions) == len(reclaimed)

        # collector footprint drained to the survivors
        assert not set(reclaimed) & set(collector.reports)
        assert len(collector.reports) <= router.replica_count + 1
    finally:
        router.close()
