"""collective_bytes(): the HLO wire-cost parser the roofline reads.

Synthetic post-SPMD HLO lines pin the ring-cost formulas, group-size
parsing (iota and explicit forms), async -start handling, and the
bf16→f32 all-reduce promotion correction (XLA:CPU promotes reduction
wires to f32; TPU reduces native bf16).
"""
import pytest

from repro.launch.hlo_cost import collective_bytes

GiB = 2**30


def test_all_gather_ring_cost():
    # result 1024 f32 = 4096 B, groups of 16 → wire = 15/16 × 4096
    hlo = ("%ag = f32[1024]{0} all-gather(%x), channel_id=1, "
           "replica_groups=[16,16]<=[256], dimensions={0}")
    total, detail = collective_bytes(hlo)
    assert total == pytest.approx(15 / 16 * 4096)
    assert detail["counts"]["all-gather"] == 1


def test_all_reduce_ring_cost():
    hlo = ("%ar = f32[1000]{0} all-reduce(%x), channel_id=2, "
           "replica_groups=[1,8]<=[8], to_apply=%add.1")
    total, _ = collective_bytes(hlo)
    assert total == pytest.approx(2 * 7 / 8 * 4000)


def test_reduce_scatter_cost():
    hlo = ("%rs = bf16[256]{0} reduce-scatter(%x), channel_id=3, "
           "replica_groups=[1,4]<=[4], dimensions={0}, to_apply=%add.2")
    total, _ = collective_bytes(hlo)
    assert total == pytest.approx(3 * 512)      # (n-1) × result


def test_collective_permute_and_async_start():
    hlo = "\n".join([
        "%cp = f32[100]{0} collective-permute(%x), channel_id=4",
        "%ag = f32[64]{0} all-gather-start(%y), channel_id=5, "
        "replica_groups=[1,2]<=[2], dimensions={0}",
    ])
    total, detail = collective_bytes(hlo)
    assert detail["bytes"]["collective-permute"] == 400
    assert detail["counts"]["all-gather"] == 1


def test_explicit_group_form():
    hlo = ("%ar = f32[8]{0} all-reduce(%x), "
           "replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add")
    total, _ = collective_bytes(hlo)
    assert total == pytest.approx(2 * 3 / 4 * 32)


def test_promoted_bf16_reduction_corrected_in_detail():
    """Promoted (bf16→f32) reductions: raw total keeps the f32 width
    (comparable on this backend); the TPU-corrected total halves them."""
    hlo = "\n".join([
        "%ar1 = f32[1000]{0} all-reduce(%a), replica_groups=[1,8]<=[8], "
        "to_apply=%add.10.clone_promoted",
        "%ar2 = f32[1000]{0} all-reduce(%b), replica_groups=[1,8]<=[8], "
        "to_apply=%add.11",
    ])
    total, detail = collective_bytes(hlo)
    one = 2 * 7 / 8 * 4000
    assert total == pytest.approx(2 * one)
    assert detail["tpu_corrected_total"] == pytest.approx(1.5 * one)


def test_single_device_groups_skipped():
    hlo = "%ar = f32[8]{0} all-reduce(%x), replica_groups=[8,1]<=[8], to_apply=%a"
    total, _ = collective_bytes(hlo)
    assert total == 0.0
