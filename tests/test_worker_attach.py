"""Concurrent worker sessions: one mutator + read-only observers.

The --listen worker's accept loop multiplexes ONE mutating session (a
router's SocketReplica) with any number of read-only observer attaches.
Pinned here:

* a second mutate attach is rejected with a TYPED WorkerBusyError (both
  via the explicit attach handshake and via a legacy implicit first op);
* an observer sees the SAME lifetime() counters the router's session sees,
  mid-decode, without draining the mutator's metric window;
* an observer severed mid-frame leaves the mutating session unharmed;
* an observer issuing a mutating op is bounced per-message with a typed
  PermissionError and the observer session survives;
* the closed loop can carry out-of-band observer attaches
  (LoopConfig.observe_addrs) whose counters match the router's fleet
  metrics at the end of the run;
* the tcp/pod factories count off-list local spawns into
  router.metrics()["off_list_spawns"] (the topology-drift signal).
"""
import socket
import struct

import numpy as np
import pytest

from repro.serving import (
    MetricsObserver, ReplicaRouter, Request, TcpReplica, WorkerBusyError,
    launch_fleet, spawn_worker,
)
from repro.serving.transport import Connection, dial

from conftest import TINY_CFGS

SLOTS = 2
MAX_SEQ = 24


def _requests(n, prompt_len=6, gen_len=4, seed=0):
    cfg = TINY_CFGS["dense"]
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(
                3, cfg.vocab, size=prompt_len).astype(np.int32),
                gen_len=gen_len) for i in range(n)]


@pytest.mark.slow
def test_second_mutator_rejected_typed_and_observers_concurrent():
    """One spawned worker: the first TcpReplica owns the mutating session;
    a second TcpReplica attach fails with WorkerBusyError (typed, no
    desync); an observer attached THROUGHOUT polls the same lifetime
    counters the router-side stub sees mid-decode — and its polls never
    perturb the token stream (asserted against a fresh identical run)."""
    cfg = TINY_CFGS["dense"]
    addr, proc = spawn_worker(once=False)
    try:
        rep = TcpReplica(cfg, slots=SLOTS, max_seq=MAX_SEQ, prefill_chunk=4,
                         addr=addr)
        obs = MetricsObserver(addr)
        with pytest.raises(WorkerBusyError):
            TcpReplica(cfg, slots=SLOTS, max_seq=MAX_SEQ, addr=addr)
        # ... the rejection did not disturb either live session
        assert obs.ping()

        reqs = _requests(4, gen_len=5)
        for r in reqs:
            rep.submit(r, now=0.0)
        done, now = [], 0.0
        mid_lifetimes = []
        while len(done) < 4 and now < 100:
            now += 1.0
            done.extend(rep.step(now))
            # concurrent poll, mid-decode: same counters both sides
            mid_lifetimes.append((obs.lifetime(), rep.lifetime()))
        assert sorted(r.rid for r in done) == [0, 1, 2, 3]
        for seen_by_observer, seen_by_router in mid_lifetimes:
            assert seen_by_observer == seen_by_router
        assert any(lt["total_completed"] > 0
                   for lt, _ in mid_lifetimes[:-1]), \
            "observer never caught the pod mid-stream"
        streams = {r.rid: tuple(r.tokens_out) for r in done}
        rep.close()
        obs.close()

        # unobserved control run on a fresh attach: identical stream
        rep2 = TcpReplica(cfg, slots=SLOTS, max_seq=MAX_SEQ, prefill_chunk=4,
                          addr=addr)
        for r in _requests(4, gen_len=5):
            rep2.submit(r, now=0.0)
        done2, now = [], 0.0
        while len(done2) < 4 and now < 100:
            now += 1.0
            done2.extend(rep2.step(now))
        assert {r.rid: tuple(r.tokens_out) for r in done2} == streams
        rep2.close()
    finally:
        if proc.poll() is None:
            proc.kill()
        proc.wait(timeout=30)


@pytest.mark.slow
def test_observer_severed_mid_frame_leaves_mutator_unharmed():
    """Write half a frame on an observer connection and slam it shut: the
    worker must drop that observer and keep serving the mutating session
    without a hiccup."""
    cfg = TINY_CFGS["dense"]
    addr, proc = spawn_worker(once=True)
    try:
        rep = TcpReplica(cfg, slots=SLOTS, max_seq=MAX_SEQ, prefill_chunk=4,
                         addr=addr)
        reqs = _requests(2, gen_len=4)
        for r in reqs:
            rep.submit(r, now=0.0)
        done = [r for r in rep.step(1.0)]

        # a raw observer that dies mid-frame: declare 64 bytes, send 3, RST
        raw = socket.create_connection(addr, timeout=10)
        conn = Connection(raw, timeout=10)
        conn.send({"op": "attach", "mode": "observe", "seq": 0})
        assert conn.recv()["ok"]
        raw.sendall(struct.pack(">I", 64) + b'{"o')
        raw.setsockopt(socket.SOL_SOCKET, socket.SO_LINGER,
                       struct.pack("ii", 1, 0))   # RST, not FIN — the rudest
        raw.close()

        now = 1.0
        while len(done) < 2 and now < 100:
            now += 1.0
            done.extend(rep.step(now))
        assert sorted(r.rid for r in done) == [0, 1]
        assert all(len(r.tokens_out) == 4 for r in done)
        assert rep.lifetime()["total_completed"] == 2
        rep.close()
    finally:
        if proc.poll() is None:
            proc.kill()
        proc.wait(timeout=30)


@pytest.mark.slow
def test_observer_stalled_mid_frame_does_not_block_mutator():
    """The sharper isolation property: an observer that sends HALF a frame
    and then goes quiet — socket alive, frame never finished — must cost
    the mutating session nothing (per-session receive buffers; the partial
    frame just parks).  When the observer finally finishes the frame, it
    gets served."""
    import time

    cfg = TINY_CFGS["dense"]
    addr, proc = spawn_worker(once=True)
    try:
        rep = TcpReplica(cfg, slots=SLOTS, max_seq=MAX_SEQ, prefill_chunk=4,
                         addr=addr)
        raw = socket.create_connection(addr, timeout=30)
        stalled = Connection(raw, timeout=30)
        stalled.send({"op": "attach", "mode": "observe", "seq": 0})
        assert stalled.recv()["ok"]
        frame = struct.pack(">I", 30) + b'{"op":"ping"'   # 12 of 30 bytes
        raw.sendall(frame)                                # ...and stall

        reqs = _requests(2, gen_len=3)
        t0 = time.monotonic()
        for r in reqs:
            rep.submit(r, now=0.0)
        done, now = [], 0.0
        while len(done) < 2 and now < 100:
            now += 1.0
            done.extend(rep.step(now))
        assert sorted(r.rid for r in done) == [0, 1]
        # the stalled half-frame cost the mutator nothing (well under the
        # 30s session send deadline — generous bound for a loaded CI box)
        assert time.monotonic() - t0 < 20.0
        raw.sendall(b',"seq":1}' + b" " * (30 - 12 - 9))  # finish the frame
        reply = stalled.recv()
        assert reply["ok"] and reply["seq"] == 1
        stalled.close()
        rep.close()
    finally:
        if proc.poll() is None:
            proc.kill()
        proc.wait(timeout=30)


def test_pod_desync_reply_reaps_replica_instead_of_crashing():
    """A PodDesyncError step reply (the head detected rank divergence)
    must surface exactly like a lost replica — stub flips failed, step
    returns, lost requests recoverable — NEVER as a driver-crashing
    RuntimeError: one drifted rank costs one pod, not the whole fleet."""
    import threading

    from repro.serving.transport import Listener

    lst = Listener("127.0.0.1", 0)

    def fake_pod_head():
        conn = lst.accept(timeout=30, conn_timeout=30)
        while True:
            msg = conn.recv()
            if msg["op"] == "step":
                conn.send({"error": "pod lockstep divergence on step",
                           "etype": "PodDesyncError", "seq": msg["seq"]})
                return
            conn.send({"ok": True, "seq": msg["seq"]})

    t = threading.Thread(target=fake_pod_head, daemon=True)
    t.start()
    cfg = TINY_CFGS["dense"]
    rep = TcpReplica(cfg, slots=SLOTS, max_seq=MAX_SEQ, prefill_chunk=4,
                     addr=lst.addr, rpc_timeout_s=30.0)
    [req] = _requests(1, gen_len=2)
    rep.submit(req, now=0.0)
    out = rep.step(1.0)                    # desync reply: no raise
    assert out == [] and rep.failed
    assert [r.rid for r in rep.lost_requests()] == [0]
    t.join(timeout=10)
    lst.close()


@pytest.mark.slow
def test_observer_mutating_op_bounced_typed_session_survives():
    cfg = TINY_CFGS["dense"]
    addr, proc = spawn_worker(once=True)
    try:
        rep = TcpReplica(cfg, slots=SLOTS, max_seq=MAX_SEQ, addr=addr)
        obs = MetricsObserver(addr)
        for bad_op in ("evacuate", "resume", "report", "step", "shutdown"):
            with pytest.raises(PermissionError):
                obs._rpc({"op": bad_op})
        # the bounces were per-message: the observer session is intact
        assert obs.ping()
        assert obs.status()["initialized"]
        obs.close()
        rep.close()
    finally:
        if proc.poll() is None:
            proc.kill()
        proc.wait(timeout=30)


@pytest.mark.slow
def test_legacy_implicit_mutator_claim_still_works():
    """A pre-attach client whose first op is init must still get the
    mutating session; a second such client bounces typed."""
    cfg = TINY_CFGS["dense"]
    from repro.serving.transport import encode_config
    addr, proc = spawn_worker(once=False)
    try:
        conn = dial(*addr, timeout=120)
        conn.send({"op": "init", "cfg": encode_config(cfg), "slots": SLOTS,
                   "max_seq": MAX_SEQ, "seed": 0, "prefill_chunk": None,
                   "replica_id": 0, "seq": 0})
        assert conn.recv()["ok"]
        late = dial(*addr, timeout=30)
        late.send({"op": "ping", "seq": 0})
        reply = late.recv()
        assert reply.get("etype") == "WorkerBusyError"
        late.close()
        conn.close()
    finally:
        if proc.poll() is None:
            proc.kill()
        proc.wait(timeout=30)


@pytest.mark.slow
def test_closed_loop_observe_addrs_out_of_band_counters():
    """The closed loop drives a tcp fleet while holding read-only observer
    attaches on the same workers: the out-of-band lifetime counters it
    logs per tick must add up to the router's own fleet metrics at the
    end — two views of one fleet, over two kinds of session."""
    from repro.serving.closed_loop import LoopConfig, run_closed_loop

    cfg = TINY_CFGS["dense"]
    with launch_fleet(2) as fleet:
        lc = LoopConfig(slots=2, max_replicas=2, max_seq=32, prefill_chunk=4,
                        steps_per_tick=6, topology="tcp",
                        addrs=tuple(fleet.addrs),
                        observe_addrs=tuple(fleet.addrs))
        router, logs = run_closed_loop(cfg, autoscale=True, ticks=6, seed=0,
                                       lc=lc)
        assert all(len(t.observed) == 2 for t in logs)
        observed_completed = sum(
            o["lifetime"]["total_completed"] for o in logs[-1].observed)
        assert observed_completed == router.metrics()["completed"] > 0
        router.close()


def test_off_list_spawns_surface_in_router_metrics():
    """An eviction replacement (or scale-up) past an explicit attach list
    spawns a LOCAL worker — stderr already warns; the count must ALSO be
    visible to the control plane via router.metrics()."""
    cfg = TINY_CFGS["dense"]
    with launch_fleet(1) as fleet:
        with pytest.warns(RuntimeWarning, match="attach list"):
            router = ReplicaRouter.from_topology(
                cfg, "tcp", slots=SLOTS, max_seq=16, prefill_chunk=4,
                n_replicas=2, max_replicas=2, addrs=fleet.addrs)
        try:
            assert router.metrics()["off_list_spawns"] == 1
            # an on-list-only fleet reports zero
        finally:
            router.close()
    router2 = ReplicaRouter.from_topology(
        cfg, "proc", slots=SLOTS, max_seq=16, prefill_chunk=4,
        n_replicas=1, max_replicas=1)
    try:
        assert router2.metrics()["off_list_spawns"] == 0
    finally:
        router2.close()
