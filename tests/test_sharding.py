"""Logical-axis partition rules: spec construction, divisibility fallback,
mesh-axis uniqueness, ambient constrain context — plus a subprocess dry-run
on an 8-device host mesh (device-count override must not leak into this
process, hence the re-exec).
"""
import os
import subprocess
import sys
from pathlib import Path

import jax
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # collection must degrade to skips, not errors
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.sharding import (
    AxisRules, SERVE_RULES, TRAIN_RULES, constrain, current_ctx, spec_for,
    tree_specs,
)

REPO = Path(__file__).resolve().parents[1]


class FakeMesh:
    """Duck-typed mesh (axis_names + devices.shape) for spec tests — a real
    multi-device Mesh cannot be built in the 1-CPU test process."""

    def __init__(self, shape, axes):
        self.axis_names = tuple(axes)
        self.devices = np.empty(shape, object)


MESH_2D = FakeMesh((16, 16), ("data", "model"))
MESH_3D = FakeMesh((2, 16, 16), ("pod", "data", "model"))


def test_basic_specs():
    rules = TRAIN_RULES
    assert spec_for(("batch", "seq"), rules, MESH_2D, (256, 4096)) == P(("data",))
    assert spec_for(("batch", "seq"), rules, MESH_3D, (256, 4096)) == \
        P(("pod", "data"))
    assert spec_for(("embed", "ff"), rules, MESH_2D, (4096, 16384)) == \
        P("data", "model")


def test_divisibility_fallback_replicates():
    # 40 heads % 16 != 0 → replicated (the known qwen2.5-14b case)
    assert spec_for(("embed", "heads"), TRAIN_RULES, MESH_2D, (5120, 40)) == \
        P("data")
    # divisible head count keeps the mapping
    assert spec_for(("embed", "heads"), TRAIN_RULES, MESH_2D, (5120, 64)) == \
        P("data", "model")


def test_batch_partial_divisibility_keeps_prefix():
    # batch 2 on ("pod","data") = (2,16): full product 32 doesn't divide, but
    # the "pod" prefix (2) does → P(("pod",))
    assert spec_for(("batch", None), TRAIN_RULES, MESH_3D, (2, 128)) == \
        P(("pod",))


def test_no_mesh_axis_used_twice():
    rules = AxisRules({"a": ("model",), "b": ("model",)})
    spec = spec_for(("a", "b"), rules, MESH_2D, (64, 64))
    assert spec == P("model")        # second use dropped


@settings(max_examples=40, deadline=None)
@given(
    dims=st.lists(st.sampled_from([1, 2, 3, 5, 8, 16, 40, 64, 256]),
                  min_size=1, max_size=4),
    names=st.lists(st.sampled_from(["batch", "embed", "heads", "ff", "vocab",
                                    "experts", None]),
                   min_size=1, max_size=4),
)
def test_spec_always_valid(dims, names):
    """Property: every produced spec (a) only names real mesh axes, (b) never
    repeats a mesh axis, (c) every sharded dim is divisible by its axis product."""
    n = min(len(dims), len(names))
    dims, names = dims[:n], names[:n]
    spec = spec_for(names, TRAIN_RULES, MESH_2D, dims)
    sizes = dict(zip(MESH_2D.axis_names, MESH_2D.devices.shape))
    seen = []
    for i, entry in enumerate(spec):
        axes = (entry,) if isinstance(entry, str) else (entry or ())
        for a in axes:
            assert a in sizes
            assert a not in seen
            seen.append(a)
        if axes:
            prod = int(np.prod([sizes[a] for a in axes]))
            assert dims[i] % prod == 0


def test_tree_specs_mirrors_structure():
    axes_tree = {"w": ("embed", "ff"), "b": ("ff",)}
    shapes = {"w": jax.ShapeDtypeStruct((128, 64), jax.numpy.float32),
              "b": jax.ShapeDtypeStruct((64,), jax.numpy.float32)}
    specs = tree_specs(axes_tree, TRAIN_RULES, MESH_2D, shapes)
    assert specs["w"] == P("data", "model")
    assert specs["b"] == P("model")


def test_serve_rules_shard_cache_seq():
    assert spec_for(("batch", "cache_seq", "kv_heads", None), SERVE_RULES,
                    MESH_2D, (128, 32768, 8, 128)) == P(("data",), "model")
    # train rules keep cache_seq replicated
    assert spec_for(("batch", "cache_seq", "kv_heads", None), TRAIN_RULES,
                    MESH_2D, (128, 32768, 8, 128)) == P(("data",))


def test_constrain_noop_without_context():
    assert current_ctx() is None
    x = jax.numpy.ones((4, 4))
    y = constrain(x, ("batch", "embed"))
    assert y is x                      # literally untouched


def test_rules_replace_is_functional():
    r2 = TRAIN_RULES.replace(cache_seq=("model",))
    assert TRAIN_RULES.get("cache_seq") == ()
    assert r2.get("cache_seq") == ("model",)


# ---------------------------------------------------------------- subprocess

SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.launch.dryrun import lower_cell
from repro.launch.mesh import make_mesh
from repro.launch.hlo_cost import collective_bytes
import dataclasses
cfg = get_smoke_config("qwen2.5-3b")
cfg = dataclasses.replace(cfg, n_layers=2)
mesh = make_mesh((2, 4), ("data", "model"))
import repro.models.config as mc
shape = mc.ShapeCfg("t", 64, 8, "train")
mc.SHAPES["t"] = shape
lowered = lower_cell(cfg, "t", mesh)
compiled = lowered.compile()
coll, detail = collective_bytes(compiled.as_text())
assert coll > 0, "expected collectives on a 2x4 mesh"
print("OK", int(coll), compiled.cost_analysis()["flops"] > 0)
"""


def test_dryrun_smoke_on_8_host_devices():
    """lower+compile a reduced train cell on a (2,4) mesh in a subprocess —
    proves the full dry-run path (shardings, donation, collectives) works
    end to end without touching this process's device count."""
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SUBPROC], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert out.stdout.startswith("OK")


def test_serve_dryrun_smoke_on_8_host_devices():
    code = SUBPROC.replace('"t", 64, 8, "train"', '"t", 64, 8, "decode"')
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert out.stdout.startswith("OK")
