"""Serving engine: FCFS admission, slot lifecycle/reuse, chunked-prefill
equivalence (chunked vs one-shot prefill produce identical greedy tokens),
generic slot-pool writes across every family's cache pytree, per-slot
positions (staggered admission must not perturb a request's tokens), the
seeded sampling layer, and the Pallas data path (use_pallas=True in interpret
mode must reproduce the jnp reference token streams end to end).
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import LM
from repro.models.steps import make_chunked_prefill_step, make_prefill_step
from repro.serving import (
    Request, SamplingParams, ServingEngine, SlotPool, sample_token,
)
from repro.serving.engine import EngineCore

from conftest import TINY_CFGS

MAX_SEQ = 24
# the issue's five families: dense, dense+sliding-window, vlm, moe, hybrid/ssm
FIVE_FAMILIES = ["dense", "swa", "vlm", "moe", "hybrid"]


@functools.lru_cache(maxsize=None)
def core_for(family: str, use_pallas: bool) -> EngineCore:
    cfg = TINY_CFGS[family]
    if use_pallas:
        cfg = dataclasses.replace(cfg, use_pallas=True)
    return EngineCore(cfg, MAX_SEQ, seed=0)


def make_engine(family: str, *, slots=2, prefill_chunk=None,
                use_pallas=False) -> ServingEngine:
    core = core_for(family, use_pallas)
    return ServingEngine(core.cfg, slots=slots, max_seq=MAX_SEQ,
                         prefill_chunk=prefill_chunk, core=core)


def make_requests(family: str, n, prompt_len=8, gen_len=4, seed=0,
                  sampling=SamplingParams()):
    cfg = TINY_CFGS[family]
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(3, cfg.vocab,
                                        size=prompt_len).astype(np.int32),
                    gen_len=gen_len, sampling=sampling) for i in range(n)]


def run_to_completion(eng, n, max_steps=500):
    done, now = [], 0.0
    for _ in range(max_steps):
        now += 1.0
        done.extend(eng.step(now=now))
        if len(done) >= n and eng.idle:
            return done
    raise AssertionError(f"only {len(done)}/{n} completed")


# ---------------------------------------------------------------- scheduler


def test_fcfs_admission_order():
    eng = make_engine("dense", slots=2)
    reqs = make_requests("dense", 5, gen_len=3)
    for r in reqs:
        eng.submit(r, now=0.0)
    eng.step(now=1.0)
    assert {r.rid for r in eng.slot_owner.values()} == {0, 1}
    done = run_to_completion(eng, 5)
    # FCFS: admission timestamps are monotone in rid
    admits = [r.t_admit for r in sorted(done, key=lambda r: r.rid)]
    assert admits == sorted(admits)
    assert sorted(r.rid for r in done) == [0, 1, 2, 3, 4]


def test_slot_reuse_and_owner_cleared_on_release():
    eng = make_engine("dense", slots=1)
    r0, r1 = make_requests("dense", 2, gen_len=2)
    eng.submit(r0, now=0.0)
    done = []
    now = 0.0
    while not done:
        now += 1.0
        done = eng.step(now=now)
    # slot released: owner cleared, phase free, prompt buffer dropped
    assert eng.slot_owner == {}
    assert not eng.active[0]
    assert eng._prompt[0] is None
    eng.submit(r1, now=now)
    done2 = run_to_completion(eng, 1)
    assert done2[0].rid == 1 and done2[0].replica_id == eng.replica_id
    assert eng.slot_owner == {}


def test_admit_rejects_busy_slot_and_bad_prompts():
    eng = make_engine("dense", slots=1)
    eng.admit(0, np.arange(3, 8, dtype=np.int32), 2)
    with pytest.raises(ValueError):
        eng.admit(0, np.arange(3, 8, dtype=np.int32), 2)
    eng2 = make_engine("dense", slots=1)
    with pytest.raises(ValueError):
        eng2.admit(0, np.zeros(0, np.int32), 2)
    with pytest.raises(ValueError):  # full-attention prompt must fit max_seq
        eng2.admit(0, np.full(MAX_SEQ, 3, np.int32), 2)


def test_gen_len_clamped_to_cache_for_full_attention():
    eng = make_engine("dense", slots=1)
    [r] = make_requests("dense", 1, prompt_len=MAX_SEQ - 4, gen_len=100)
    eng.submit(r, now=0.0)
    done = run_to_completion(eng, 1)
    assert len(done[0].tokens_out) == 4          # max_seq - prompt_len


# ------------------------------------------------- chunked-prefill equivalence


@pytest.mark.parametrize("family", FIVE_FAMILIES + ["ssm2"])
def test_chunked_prefill_step_matches_one_shot(family):
    cfg = TINY_CFGS[family]
    params = core_for(family, False).params
    rng = np.random.default_rng(3)
    prompt = rng.integers(3, cfg.vocab, size=12).astype(np.int32)
    inputs = {"tokens": jnp.asarray(prompt[None])}
    if cfg.family == "vlm":
        inputs["patches"] = jnp.zeros(
            (1, cfg.n_vision_patches, cfg.d_model), cfg.cdtype)
    one_l, one_c = make_prefill_step(cfg, MAX_SEQ)(params, inputs)
    chunk = 6 if cfg.family != "vlm" else cfg.n_vision_patches + 2
    chk_l, chk_c = make_chunked_prefill_step(cfg, MAX_SEQ, chunk)(params,
                                                                  inputs)
    assert int(jnp.argmax(one_l[0, -1])) == int(jnp.argmax(chk_l[0, -1]))
    assert int(one_c["index"]) == int(chk_c["index"]) == len(prompt)
    np.testing.assert_allclose(np.asarray(one_l[:, -1], np.float32),
                               np.asarray(chk_l[:, -1], np.float32),
                               atol=5e-5, rtol=5e-5)


def test_chunked_prefill_step_rejects_chunk_inside_patch_prefix():
    cfg = TINY_CFGS["vlm"]
    with pytest.raises(ValueError):
        make_chunked_prefill_step(cfg, MAX_SEQ, cfg.n_vision_patches)


@pytest.mark.parametrize("family", FIVE_FAMILIES)
def test_engine_streamed_prefill_matches_one_shot(family):
    """Admission with a small prefill chunk streams the prompt tail through
    the decode tick — the full greedy token stream must be identical to a
    whole-prompt prefill."""
    reqs = make_requests(family, 2, prompt_len=10, gen_len=4, seed=7)
    reqs[1].prompt = reqs[0].prompt.copy()
    one = make_engine(family, slots=1, prefill_chunk=None)
    one.submit(reqs[0], now=0.0)
    [done_one] = run_to_completion(one, 1)
    chunked = make_engine(family, slots=1, prefill_chunk=3)
    chunked.submit(reqs[1], now=0.0)
    [done_chk] = run_to_completion(chunked, 1)
    assert done_one.tokens_out == done_chk.tokens_out
    assert len(done_chk.tokens_out) == 4
    # streamed prefill takes decode ticks, so TTFT comes later but exists
    assert done_chk.t_first_token is not None


# ------------------------------------------------------------- slot pool


@pytest.mark.parametrize("family", FIVE_FAMILIES + ["ssm2"])
def test_write_slot_axis_detection_per_family(family):
    # (audio/enc-dec is covered by the dedicated enc-dec tests below: its
    # prefill cross K/V is encoder-length and write_slot zero-pads it up to
    # the max_seq-sized pool spec, so the exact-row comparison here — pool
    # row == one-cache row — would not hold leaf-for-leaf)
    cfg = TINY_CFGS[family]
    params = core_for(family, False).params
    rng = np.random.default_rng(0)

    def one_cache(n):
        inputs = {"tokens": jnp.asarray(
            rng.integers(3, cfg.vocab, size=n).astype(np.int32)[None])}
        if cfg.family == "vlm":
            inputs["patches"] = jnp.zeros(
                (1, cfg.n_vision_patches, cfg.d_model), cfg.cdtype)
        if cfg.enc_dec:
            inputs["frames"] = jnp.zeros((1, n, cfg.d_model), cfg.cdtype)
        return LM.prefill(params, inputs, cfg, MAX_SEQ)[1]

    c0, c2 = one_cache(6), one_cache(5)
    pool = SlotPool(cfg, 3, MAX_SEQ)
    pool.write(c0, 0)
    pool.write(c2, 2)
    assert [int(v) for v in pool.index] == [6, 0, 5]

    def batch_axis(pool_leaf, one_leaf):
        for ax in range(pool_leaf.ndim):
            if one_leaf.shape[ax] == 1 and pool_leaf.shape[ax] != 1:
                return ax
        raise AssertionError("no batch axis found")

    rest_pool = {k: v for k, v in pool.cache.items() if k != "index"}
    rest_one0 = {k: v for k, v in c0.items() if k != "index"}
    checked = []

    def check(p, o):
        p, o = np.asarray(p), np.asarray(o)
        ax = batch_axis(p, o)
        np.testing.assert_array_equal(np.take(p, 0, axis=ax),
                                      np.take(o, 0, axis=ax))
        np.testing.assert_array_equal(np.take(p, 1, axis=ax),
                                      np.zeros_like(np.take(p, 1, axis=ax)))
        checked.append(ax)
        return p

    jax.tree.map(check, rest_pool, rest_one0)
    assert checked                                  # every family has leaves
    if family == "hybrid":                          # mamba states: batch at 2
        assert 2 in checked and 1 in checked


def test_write_slot_single_slot_pool_is_overwrite():
    """A 1-slot pool has identical pool/one shapes; the seed's axis scan
    silently dropped the write — it must be a whole-pool overwrite."""
    cfg = TINY_CFGS["dense"]
    params = core_for("dense", False).params
    prompt = np.arange(3, 9, dtype=np.int32)
    _, one = LM.prefill(params, {"tokens": jnp.asarray(prompt[None])}, cfg,
                        MAX_SEQ)
    pool = SlotPool(cfg, 1, MAX_SEQ)
    assert float(jnp.abs(pool.cache["layers"]["k"]).sum()) == 0.0
    pool.write(one, 0)
    np.testing.assert_array_equal(pool.cache["layers"]["k"],
                                  one["layers"]["k"])
    assert int(pool.index[0]) == len(prompt)


# ------------------------------------------------------- per-slot positions


@pytest.mark.parametrize("family", ["dense", "swa", "vlm"])
def test_staggered_admission_does_not_perturb_tokens(family):
    """A request admitted mid-flight (other slots deep into decode) must
    produce exactly the tokens it produces alone — per-slot ring positions,
    RoPE angles, and validity masks (the seed's shared scalar index failed
    this)."""
    ra, rb, rb_solo = make_requests(family, 3, prompt_len=8, gen_len=6,
                                    seed=11)
    rb_solo.prompt = rb.prompt.copy()

    solo = make_engine(family, slots=2)
    solo.submit(rb_solo, now=0.0)
    [done_solo] = run_to_completion(solo, 1)

    eng = make_engine(family, slots=2)
    eng.submit(ra, now=0.0)
    now = 0.0
    for _ in range(3):                              # ra is 3 tokens deep
        now += 1.0
        eng.step(now=now)
    eng.submit(rb, now=now)
    done = run_to_completion(eng, 2)
    by_rid = {r.rid: r for r in done}
    assert by_rid[rb.rid].tokens_out == done_solo.tokens_out


# ------------------------------------------------- pallas engine equivalence


def _staggered_run(family: str, use_pallas: bool):
    """Staggered-admission run: 3 requests through 2 slots, the third
    admitted while the first two are mid-decode — exercises the vector-index
    decode path (mixed per-row ring positions) every tick."""
    reqs = make_requests(family, 3, prompt_len=8, gen_len=5, seed=23)
    eng = make_engine(family, slots=2, use_pallas=use_pallas)
    eng.submit(reqs[0], now=0.0)
    eng.submit(reqs[1], now=0.0)
    now = 0.0
    for _ in range(2):                          # first two are 2 tokens deep
        now += 1.0
        eng.step(now=now)
    eng.submit(reqs[2], now=now)
    done = run_to_completion(eng, 3)
    return {r.rid: r.tokens_out for r in done}


@pytest.mark.parametrize("family", FIVE_FAMILIES)
def test_pallas_engine_matches_jnp_token_streams(family):
    """ServingEngine with use_pallas=True (fused vector-index decode kernel +
    ring-scatter K/V write, interpret mode) must emit exactly the token
    streams of the jnp reference engine under staggered admission."""
    want = _staggered_run(family, use_pallas=False)
    got = _staggered_run(family, use_pallas=True)
    assert got == want


def test_pallas_vector_decode_tick_matches_jnp_cache():
    """One decode tick over a staggered pool: the pallas engine's KV cache
    and the jnp engine's must agree (the ring scatter wrote the same slots)."""
    engines = {}
    for use_pallas in (False, True):
        reqs = make_requests("dense", 2, prompt_len=6, gen_len=4, seed=29)
        eng = make_engine("dense", slots=2, use_pallas=use_pallas)
        eng.submit(reqs[0], now=0.0)
        eng.step(now=1.0)                       # slot 0 one tick ahead
        eng.submit(reqs[1], now=1.0)
        eng.step(now=2.0)
        engines[use_pallas] = eng
    k_ref = np.asarray(engines[False].pool.cache["layers"]["k"], np.float32)
    k_pal = np.asarray(engines[True].pool.cache["layers"]["k"], np.float32)
    np.testing.assert_allclose(k_pal, k_ref, atol=1e-5, rtol=1e-5)
    np.testing.assert_array_equal(
        np.asarray(engines[True].pool.index), np.asarray(engines[False].pool.index))


# ------------------------------------------------------------- enc-dec


def _audio_request(rid, enc_len, *, prompt_len=6, gen_len=4, seed=0):
    cfg = TINY_CFGS["audio"]
    rng = np.random.default_rng((seed, rid))
    return Request(
        rid=rid,
        prompt=rng.integers(3, cfg.vocab, size=prompt_len).astype(np.int32),
        gen_len=gen_len,
        frames=rng.standard_normal((enc_len, cfg.d_model)).astype(np.float32))


def test_enc_dec_slot_serving():
    """The PR-2 gap, closed: the engine admits ``frames`` and the slot pool
    zero-pads prefill's encoder-length cross K/V up to the max_seq-sized
    pool spec (the pad rows sit past cross_len and are masked at decode).
    Two requests with DIFFERENT encoder lengths, staggered so their ring
    positions and cross lengths differ every tick, must each produce
    exactly the tokens they produce alone."""
    solo = {}
    for rid, enc_len in ((0, 5), (1, 9)):
        eng = make_engine("audio", slots=2, prefill_chunk=4)
        eng.submit(_audio_request(rid, enc_len), now=0.0)
        [done] = run_to_completion(eng, 1)
        solo[rid] = done.tokens_out

    eng = make_engine("audio", slots=2, prefill_chunk=4)
    eng.submit(_audio_request(0, 5), now=0.0)
    now = 0.0
    for _ in range(2):                     # request 0 is 2 tokens deep
        now += 1.0
        eng.step(now=now)
    eng.submit(_audio_request(1, 9), now=now)
    done = run_to_completion(eng, 2)
    assert {r.rid: r.tokens_out for r in done} == solo


def test_enc_dec_slot_serving_seamless_m4t_smoke():
    """The same staggered mixed-encoder-length check on the repo's actual
    seamless-m4t smoke config (tied embeddings, LayerNorm family path)."""
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("seamless-m4t-medium")
    eng_solo = ServingEngine(cfg, slots=2, max_seq=MAX_SEQ, prefill_chunk=4)
    rng = np.random.default_rng(3)

    def req(rid, enc_len):
        r = np.random.default_rng((3, rid))
        return Request(rid=rid,
                       prompt=r.integers(3, cfg.vocab, size=6
                                         ).astype(np.int32),
                       gen_len=4,
                       frames=r.standard_normal(
                           (enc_len, cfg.d_model)).astype(np.float32))

    eng_solo.submit(req(1, 9), now=0.0)
    [solo] = run_to_completion(eng_solo, 1)

    eng = ServingEngine(cfg, slots=2, max_seq=MAX_SEQ, prefill_chunk=4,
                        core=eng_solo.core)
    eng.submit(req(0, 5), now=0.0)
    now = 0.0
    for _ in range(2):
        now += 1.0
        eng.step(now=now)
    eng.submit(req(1, 9), now=now)
    done = run_to_completion(eng, 2)
    by_rid = {r.rid: r.tokens_out for r in done}
    assert by_rid[1] == solo.tokens_out
    assert all(len(t) == 4 for t in by_rid.values())


def test_enc_dec_streamed_prefill_matches_one_shot():
    """The decoder-prompt tail streams through the decode tick (cross K/V
    are already pooled from admission's one-shot encoder pass) — chunked
    and whole-prompt admission must emit identical tokens."""
    one = make_engine("audio", slots=1, prefill_chunk=None)
    one.submit(_audio_request(0, 7, prompt_len=10), now=0.0)
    [done_one] = run_to_completion(one, 1)
    chunked = make_engine("audio", slots=1, prefill_chunk=3)
    chunked.submit(_audio_request(0, 7, prompt_len=10), now=0.0)
    [done_chk] = run_to_completion(chunked, 1)
    assert done_one.tokens_out == done_chk.tokens_out


def test_enc_dec_submit_rejects_missing_or_oversized_frames():
    eng = make_engine("audio", slots=1)
    cfg = TINY_CFGS["audio"]
    req = _audio_request(0, 5)
    req.frames = None
    with pytest.raises(ValueError):
        eng.submit(req, now=0.0)
    with pytest.raises(ValueError):        # encoder must fit the cross pool
        eng.submit(_audio_request(1, MAX_SEQ + 1), now=0.0)
    with pytest.raises(ValueError):        # d_model mismatch
        bad = _audio_request(2, 5)
        bad.frames = np.zeros((5, cfg.d_model + 1), np.float32)
        eng.submit(bad, now=0.0)


# ------------------------------------------------------------- sampling


def test_greedy_sampling_is_argmax():
    logits = np.array([0.1, 2.0, -1.0, 2.0])
    assert sample_token(logits, SamplingParams()) == 1        # first max wins
    # top_k=1 collapses to the (unique) max regardless of temperature
    assert sample_token(np.array([0.1, 3.0, -1.0, 2.0]),
                        SamplingParams(temperature=0.7, top_k=1),
                        np.random.default_rng(0)) == 1


def test_seeded_sampling_is_deterministic_per_request():
    sampling = SamplingParams(temperature=0.9, top_k=4, seed=5)
    [r1] = make_requests("dense", 1, gen_len=6, sampling=sampling)
    [r2] = make_requests("dense", 1, gen_len=6, sampling=sampling)
    e1, e2 = make_engine("dense", slots=1), make_engine("dense", slots=1)
    e1.submit(r1, now=0.0)
    e2.submit(r2, now=0.0)
    [d1] = run_to_completion(e1, 1)
    [d2] = run_to_completion(e2, 1)
    assert d1.tokens_out == d2.tokens_out
    assert len(d1.tokens_out) == 6


def test_temperature_zero_matches_greedy_engine_default():
    [r_explicit] = make_requests("dense", 1, gen_len=5,
                                 sampling=SamplingParams(temperature=0.0))
    [r_default] = make_requests("dense", 1, gen_len=5)
    e1, e2 = make_engine("dense", slots=1), make_engine("dense", slots=1)
    e1.submit(r_explicit, now=0.0)
    e2.submit(r_default, now=0.0)
    [d1] = run_to_completion(e1, 1)
    [d2] = run_to_completion(e2, 1)
    assert d1.tokens_out == d2.tokens_out
