"""The paper's control plane: forecaster, DynamicScaler (§3.3.2), predictive
allocator (§3.3.1), strategy selection + rollout/canary (§3.4), monitoring +
adaptation (§3.5).  Property tests pin the safety envelope: decisions never
violate constraints regardless of metric values.
"""
import dataclasses

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # collection must degrade to skips, not errors
from hypothesis import given, settings, strategies as st

from repro.core.allocation.forecaster import WorkloadForecaster
from repro.core.allocation.rl import ACTIONS, reward_fn
from repro.core.monitoring.adapt import AdaptiveOptimizer
from repro.core.monitoring.anomaly import AnomalyDetector, trend
from repro.core.monitoring.collector import MetricsCollector, ReplicaReport
from repro.core.orchestration.rollout import (
    CanaryAnalyzer, CanarySample, Phase, RolloutManager,
    binomial_z_pvalue, welch_t_pvalue_one_sided,
)
from repro.core.orchestration.selector import (
    DecisionTreeSelector, DeploymentContext, OutcomeStats,
)
from repro.core.orchestration.strategies import (
    CATALOG, DeployEnv, total_deploy_seconds,
)
from repro.core.scaling.scaler import (
    DynamicScaler, ScalingConstraints, ScalingOptimizer,
)
from repro.sim.baseline import ThresholdAutoscaler, traditional_deploy_seconds


def linear_perf_model(replicas: int, rps: float):
    """Simple capacity model: each replica serves 10 rps at 100 ms; latency
    blows past the SLO when utilization > 1."""
    cap = replicas * 10.0
    util = min(rps / max(cap, 1e-9), 2.0)
    lat = 100.0 if util <= 0.8 else 100.0 + 800.0 * (util - 0.8)
    return lat, min(util, 1.0)


# ---------------------------------------------------------------- forecaster

def test_forecaster_learns_diurnal_pattern():
    tpd = 48
    f = WorkloadForecaster(ticks_per_day=tpd)
    t = np.arange(6 * tpd)
    series = 100 + 50 * np.sin(2 * np.pi * t / tpd)
    for v in series[:5 * tpd]:
        f.update(v)
    errs = []
    for v in series[5 * tpd:]:
        errs.append(abs(f.predict(1) - v))
        f.update(v)
    # after five days the one-step error is a small fraction of the amplitude
    assert np.mean(errs) < 12.0, np.mean(errs)


def test_forecaster_peak_geq_mean_prediction():
    f = WorkloadForecaster(ticks_per_day=24)
    for v in 100 + 10 * np.random.default_rng(0).standard_normal(100):
        f.update(v)
    assert f.predict_peak(5) >= f.predict(1) - 1e-9


def test_forecaster_nonnegative():
    f = WorkloadForecaster(ticks_per_day=24)
    for v in (5.0, 1.0, 0.5, 0.1):
        f.update(v)
    assert f.predict(1) >= 0.0


# ---------------------------------------------------------------- scaler

@settings(max_examples=40, deadline=None)
@given(
    current=st.integers(1, 64),
    load=st.floats(0.0, 5000.0),
    max_step=st.integers(1, 8),
)
def test_scaler_respects_constraints(current, load, max_step):
    c = ScalingConstraints(min_replicas=1, max_replicas=64, max_step=max_step)
    opt = ScalingOptimizer(linear_perf_model)
    d = opt.optimize(current_load={}, predicted_load=load, efficiency=0.5,
                     constraints=c, current_replicas=current)
    assert c.min_replicas <= d.target_replicas <= c.max_replicas
    assert abs(d.delta) <= max_step


def test_scaler_scales_up_for_load():
    c = ScalingConstraints(slo_ms=200.0, max_step=8)
    opt = ScalingOptimizer(linear_perf_model)
    d = opt.optimize(current_load={}, predicted_load=300.0, efficiency=0.5,
                     constraints=c, current_replicas=4)
    assert d.delta > 0           # 4 replicas = 40 rps capacity, need ~37+


def test_scaler_picks_cheapest_feasible():
    c = ScalingConstraints(slo_ms=200.0, max_step=32, max_replicas=64)
    opt = ScalingOptimizer(linear_perf_model)
    d = opt.optimize(current_load={}, predicted_load=100.0, efficiency=0.5,
                     constraints=c, current_replicas=32)
    # 100 rps at util<=0.85 → 12 replicas suffice; optimizer must shrink
    assert d.target_replicas <= 16


def test_scaler_downscale_hysteresis_and_cooldown():
    """Scale-down requires the optimizer to propose a lower target for
    down_sustain consecutive ticks, and is then rate-limited by cooldown."""
    f = WorkloadForecaster(ticks_per_day=24)
    for v in (50.0,) * 10:
        f.update(v)
    s = DynamicScaler(f, linear_perf_model, horizon_ticks=2, down_sustain=3)
    c = ScalingConstraints(cooldown_ticks=5, max_step=8)
    m = {"rps": 50.0, "rps_window": [50.0] * 4, "flop_util": 0.2}
    d1 = s.compute_scaling_decision(m, c, current_replicas=32)
    d2 = s.compute_scaling_decision(m, c, current_replicas=32)
    assert d1.delta == 0 and d1.reason == "down_hysteresis"
    assert d2.delta == 0 and d2.reason == "down_hysteresis"
    d3 = s.compute_scaling_decision(m, c, current_replicas=32)
    assert d3.delta < 0                       # sustained for 3 ticks → down
    d4 = s.compute_scaling_decision(m, c, current_replicas=d3.target_replicas)
    assert d4.delta == 0                      # hysteresis counter restarted


def test_cluster_scale_down_cancels_cold_replicas_first():
    from repro.sim import Cluster
    c = Cluster(seed=0)
    c.scale_to(4)
    c.tick = 10**6                            # 4 warm replicas
    c.scale_to(6)                             # +2 cold (provisioning)
    assert c.ready_replicas() == 4
    c.scale_to(4)                             # must cancel the 2 cold ones
    assert c.ready_replicas() == 4 and c.total_replicas() == 4


def test_scaler_analyze_current_load():
    f = WorkloadForecaster()
    s = DynamicScaler(f, linear_perf_model)
    stats = s.analyze_current_load({"rps_window": [10.0, 20.0, 30.0]})
    assert stats["peak"] == 30.0 and stats["current"] == 30.0
    assert stats["mean"] == pytest.approx(20.0)


# ---------------------------------------------------------------- reward

def test_reward_prefers_good_operating_points():
    good = reward_fn(utilization=0.8, latency_ms=150, slo_ms=200,
                     cost_per_tick=1.0, cost_scale=10.0)
    slo_violation = reward_fn(utilization=0.9, latency_ms=400, slo_ms=200,
                              cost_per_tick=1.0, cost_scale=10.0)
    wasteful = reward_fn(utilization=0.2, latency_ms=150, slo_ms=200,
                         cost_per_tick=8.0, cost_scale=10.0)
    assert good > slo_violation and good > wasteful


# ---------------------------------------------------------------- selector

def test_tree_selector_branches():
    t = DecisionTreeSelector()
    base = dict(model_params_b=7, traffic_rps=500, slo_ms=200,
                error_budget=0.01, spare_capacity_frac=0.2,
                cost_sensitivity=0.5, is_critical=True)
    assert t.select(DeploymentContext(**base)) == "canary_10"
    assert t.select(DeploymentContext(**{**base, "model_params_b": 70})) \
        == "canary_progressive"
    assert t.select(DeploymentContext(**{**base, "model_params_b": 70,
                                         "spare_capacity_frac": 0.02})) \
        == "rolling"
    assert t.select(DeploymentContext(**{**base, "is_critical": False,
                                         "traffic_rps": 2})) == "all_at_once"
    assert t.select(DeploymentContext(**{**base, "spare_capacity_frac": 1.2,
                                         "cost_sensitivity": 0.1})) \
        == "blue_green"


def test_outcome_stats_rollback_rate():
    s = OutcomeStats()
    s.record("canary_10", deploy_s=100, rolled_back=False)
    s.record("canary_10", deploy_s=120, rolled_back=True)
    assert s.rollback_rate("canary_10") == pytest.approx(0.5)
    assert s.rollback_rate("rolling") == 0.0


# ---------------------------------------------------------------- deploy time

def test_deploy_time_traditional_vs_optimized():
    """The §4.1.1 structure: traditional (sequential + manual gates + cold
    compile cache) must be substantially slower than an optimized strategy."""
    env = DeployEnv(params_bytes=14e9, chips_per_replica=16, n_replicas=16,
                    tick_s=120.0)
    trad = traditional_deploy_seconds(env)
    fast = total_deploy_seconds(CATALOG["canary_progressive"], env)
    assert trad > 1.4 * fast
    assert trad > 1800          # tens of minutes, like the paper's 45 min


def test_all_strategies_end_at_full_traffic():
    for s in CATALOG.values():
        assert s.stages[-1] == 1.0 or s.name == "shadow"


# ---------------------------------------------------------------- canary

def test_welch_detects_regression():
    rng = np.random.default_rng(0)
    control = rng.normal(100, 10, 400)
    canary_bad = rng.normal(130, 10, 400)
    canary_ok = rng.normal(100, 10, 400)
    assert welch_t_pvalue_one_sided(canary_bad, control) < 0.01
    assert welch_t_pvalue_one_sided(canary_ok, control) > 0.05


def test_binomial_detects_error_spike():
    assert binomial_z_pvalue(40, 1000, 5, 1000) < 0.01
    assert binomial_z_pvalue(6, 1000, 5, 1000) > 0.05


def _sample(rng, lat_mean, err_rate=0.001, util=0.6, n=400):
    return CanarySample(latencies_ms=rng.normal(lat_mean, 8, n),
                        n_requests=n, n_errors=int(err_rate * n),
                        utilization=util)


def test_rollout_completes_when_healthy():
    rng = np.random.default_rng(1)
    env = DeployEnv(params_bytes=1e9, chips_per_replica=16, n_replicas=8)
    mgr = RolloutManager("canary_10", env)
    mgr.start()
    for _ in range(20):
        if mgr.state.phase in (Phase.COMPLETED, Phase.ROLLED_BACK):
            break
        mgr.tick(canary=_sample(rng, 100), control=_sample(rng, 100))
    assert mgr.state.phase == Phase.COMPLETED
    assert mgr.state.traffic_frac == 1.0
    assert not mgr.state.rolled_back


def test_rollout_rolls_back_on_latency_regression():
    rng = np.random.default_rng(2)
    env = DeployEnv(params_bytes=1e9, chips_per_replica=16, n_replicas=8)
    mgr = RolloutManager("canary_10", env)
    mgr.start()
    for _ in range(20):
        if mgr.state.phase in (Phase.COMPLETED, Phase.ROLLED_BACK):
            break
        mgr.tick(canary=_sample(rng, 150), control=_sample(rng, 100))
    assert mgr.state.phase == Phase.ROLLED_BACK
    assert mgr.state.traffic_frac == 0.0


def test_rollout_tolerates_tiny_regression():
    """Practical-significance guard: a 2% latency delta on huge samples is
    statistically significant but must NOT roll back (min 5% regression)."""
    rng = np.random.default_rng(3)
    env = DeployEnv(params_bytes=1e9, chips_per_replica=16, n_replicas=8)
    mgr = RolloutManager("canary_10", env)
    mgr.start()
    for _ in range(20):
        if mgr.state.phase in (Phase.COMPLETED, Phase.ROLLED_BACK):
            break
        mgr.tick(canary=_sample(rng, 102, n=5000),
                 control=_sample(rng, 100, n=5000))
    assert mgr.state.phase == Phase.COMPLETED


def test_rollout_error_spike_rolls_back():
    rng = np.random.default_rng(4)
    env = DeployEnv(params_bytes=1e9, chips_per_replica=16, n_replicas=8)
    mgr = RolloutManager("canary_progressive", env)
    mgr.start()
    for _ in range(30):
        if mgr.state.phase in (Phase.COMPLETED, Phase.ROLLED_BACK):
            break
        mgr.tick(canary=_sample(rng, 100, err_rate=0.05),
                 control=_sample(rng, 100, err_rate=0.001))
    assert mgr.state.phase == Phase.ROLLED_BACK


# ---------------------------------------------------------------- monitoring

def test_collector_aggregates_and_flags_stragglers():
    c = MetricsCollector(straggler_factor=1.5)
    for rid in range(4):
        lat = [100.0] * 10 if rid != 3 else [400.0] * 10
        c.submit(ReplicaReport(replica_id=rid, tick=0, latency_ms_samples=lat,
                               n_requests=10, n_errors=0, flop_util=0.5,
                               hbm_util=0.4, ici_util=0.3, mem_frac=0.6,
                               queue_depth=2))
    rec = c.aggregate(0, n_replicas=4, max_replicas=8)
    assert rec["rps"] == 40
    assert rec["replicas_frac"] == 0.5
    assert 100 <= rec["latency_p50"] <= 400
    assert c.stragglers() == [3]


def test_collector_decays_stale_replicas():
    c = MetricsCollector()
    c.submit(ReplicaReport(0, tick=0, latency_ms_samples=[100], n_requests=5,
                           n_errors=0, flop_util=1.0, hbm_util=1.0,
                           ici_util=1.0, mem_frac=1.0, queue_depth=0))
    rec = c.aggregate(3, n_replicas=1, max_replicas=8)   # 3 ticks stale
    assert rec["flop_util"] == pytest.approx(0.125)      # 0.5^3


def test_anomaly_detector_flags_spike_only():
    d = AnomalyDetector(z_threshold=4.0, min_history=8)
    rng = np.random.default_rng(5)
    anomalies = []
    for t in range(60):
        v = 100 + rng.normal(0, 2) + (500 if t == 50 else 0)
        anomalies += d.update(t, {"rps": v})
    assert any(a.tick == 50 and a.kind == "spike" for a in anomalies)
    assert all(a.tick == 50 for a in anomalies)          # no false positives


def test_trend_estimator():
    assert trend(np.arange(50.0)) == pytest.approx(1.0, abs=0.05)
    assert abs(trend(np.full(50, 7.0))) < 1e-9


def test_adaptive_optimizer_moves_knobs_within_bounds():
    a = AdaptiveOptimizer(eval_window=4)
    for i in range(40):
        a.push({"flop_util": 0.5}, violations=i % 3, cost=1.0)
        st = a.maybe_adapt()
    assert 1 <= a.state.horizon <= 12
    assert 1 <= a.state.cooldown <= 12
    assert 0.6 <= a.state.util_hi <= 0.95
    base = ScalingConstraints()
    c = a.constraints(base)
    assert c.cooldown_ticks == a.state.cooldown


# ---------------------------------------------------------------- baseline

def test_threshold_autoscaler_is_reactive_with_patience():
    t = ThresholdAutoscaler(hi=0.8, lo=0.3, patience=2, max_step=2)
    assert t.decide({"flop_util": 0.9}, 4) == 4      # patience 1
    assert t.decide({"flop_util": 0.9}, 4) == 6      # fires
    assert t.decide({"flop_util": 0.5}, 6) == 6      # in band
    assert t.decide({"flop_util": 0.1}, 6) == 6
    assert t.decide({"flop_util": 0.1}, 6) == 5      # down by 1
