"""Vector-index decode kernel: fused-vs-reference equivalence suite.

The split-K Pallas decode kernel accepts a (B,) per-row cache position
(continuous batching — every serving slot sits at its own ring position).
The suite sweeps (B, KV, G, hd, Smax, block_k) and index regimes — all-zero,
fresh (< Smax), ring-wrapped (>= Smax), and mixed batches — in interpret
mode, asserting the kernel matches the pure-jnp oracle; fixed cases pin the
degenerate edges and the per-row ring-scatter write.  A deterministic grid
always runs; hypothesis (when installed) fuzzes the same property over the
full cartesian space.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:                       # degrade to the fixed grid, never to a dead module
    from hypothesis import given, settings, strategies as st
except ImportError:
    st = None

from repro.kernels import ops, ref
from repro.kernels.decode_attention import (
    cache_ring_update_bs,
    decode_attention_bkgd,
)

jax.config.update("jax_enable_x64", False)

pytestmark = pytest.mark.kernels

ATOL = 5e-5          # well inside the issue's ≤1e-3 acceptance bound


def _case(seed, B, Smax, H, KV, hd):
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (B, 1, H, hd), jnp.float32)
    kc = jax.random.normal(jax.random.fold_in(key, 1), (B, Smax, KV, hd))
    vc = jax.random.normal(jax.random.fold_in(key, 2), (B, Smax, KV, hd))
    return q, kc, vc


def _index_vector(regime, rng, B, Smax):
    if regime == "zeros":
        return np.zeros(B, np.int32)
    if regime == "fresh":
        return rng.integers(0, Smax, size=B).astype(np.int32)
    if regime == "wrapped":
        return rng.integers(Smax, 4 * Smax, size=B).astype(np.int32)
    fresh = rng.integers(0, Smax, size=B)
    wrapped = rng.integers(Smax, 4 * Smax, size=B)
    pick = rng.integers(0, 2, size=B).astype(bool)
    return np.where(pick, wrapped, fresh).astype(np.int32)


# ------------------------------------------------------- fused vs reference


def _check_vector_index(B, Smax, KV, G, hd, block_k, regime, seed):
    H = KV * G
    q, kc, vc = _case(seed, B, Smax, H, KV, hd)
    index = jnp.asarray(
        _index_vector(regime, np.random.default_rng(seed), B, Smax))
    out = ops.decode_attention(q, kc, vc, index, block_k=block_k,
                               interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, index)
    np.testing.assert_allclose(out, want, atol=ATOL, rtol=ATOL)


GRID = [
    # (B, Smax, KV, G, hd, block_k, regime)
    (1, 128, 1, 4, 16, 32, "zeros"),
    (2, 128, 2, 2, 32, 64, "fresh"),
    (4, 128, 2, 1, 32, 128, "wrapped"),
    (2, 256, 4, 2, 64, 64, "mixed"),
    (4, 256, 2, 2, 16, 128, "mixed"),
    (1, 256, 1, 1, 64, 256, "wrapped"),
    (2, 128, 1, 2, 32, 128, "zeros"),
    (4, 256, 2, 4, 32, 64, "fresh"),
]


@pytest.mark.parametrize("B,Smax,KV,G,hd,block_k,regime", GRID)
def test_vector_index_matches_ref_grid(B, Smax, KV, G, hd, block_k, regime):
    _check_vector_index(B, Smax, KV, G, hd, block_k, regime,
                        seed=B * Smax + KV + G + hd + block_k)


if st is not None:
    @settings(max_examples=24, deadline=None)
    @given(
        B=st.sampled_from([1, 2, 4]),
        Smax=st.sampled_from([128, 256]),
        KVG=st.sampled_from([(1, 4), (2, 2), (2, 1), (4, 2)]),   # (KV, G)
        hd=st.sampled_from([16, 32, 64]),
        block_k=st.sampled_from([32, 64, 128]),
        regime=st.sampled_from(["zeros", "fresh", "wrapped", "mixed"]),
        seed=st.integers(0, 2**16),
    )
    def test_vector_index_matches_ref_fuzz(B, Smax, KVG, hd, block_k, regime,
                                           seed):
        KV, G = KVG
        _check_vector_index(B, Smax, KV, G, hd, block_k, regime, seed)


def test_vector_of_equal_rows_matches_scalar_dispatch():
    """A constant (B,) vector and the scalar fast path are the same math."""
    B, Smax, H, KV, hd = 3, 256, 4, 2, 32
    q, kc, vc = _case(5, B, Smax, H, KV, hd)
    vec = ops.decode_attention(q, kc, vc, jnp.full((B,), 77, jnp.int32),
                               block_k=64, interpret=True)
    scal = ops.decode_attention(q, kc, vc, 77, block_k=64, interpret=True)
    np.testing.assert_allclose(vec, scal, atol=ATOL, rtol=ATOL)


def test_all_zero_index_reads_only_slot_zero():
    """index[b] == 0 ⇒ each row's output is exactly its v[0] row."""
    B, Smax, H, KV, hd = 2, 128, 4, 2, 32
    q, kc, vc = _case(7, B, Smax, H, KV, hd)
    out = ops.decode_attention(q, kc, vc, jnp.zeros((B,), jnp.int32),
                               block_k=64, interpret=True)
    want = jnp.repeat(vc[:, 0:1], H // KV, axis=2).reshape(B, 1, H, hd)
    np.testing.assert_allclose(out, want, atol=ATOL, rtol=ATOL)


def test_mixed_fresh_and_wrapped_rows():
    """One admitted-yesterday row (ring-wrapped) next to a fresh admission:
    the wrapped row attends to the whole cache, the fresh row only to its
    prefix — per-row horizons, one kernel launch."""
    B, Smax, H, KV, hd = 2, 128, 4, 2, 32
    q, kc, vc = _case(9, B, Smax, H, KV, hd)
    index = jnp.asarray([3 * Smax + 5, 2], jnp.int32)
    out = ops.decode_attention(q, kc, vc, index, block_k=32, interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, index)
    np.testing.assert_allclose(out, want, atol=ATOL, rtol=ATOL)
    # row 1 must be invariant to garbage beyond its horizon
    kc2 = kc.at[1, 3:].set(1e3)
    vc2 = vc.at[1, 3:].set(-1e3)
    out2 = ops.decode_attention(q, kc2, vc2, index, block_k=32, interpret=True)
    np.testing.assert_allclose(out2[1], out[1], atol=ATOL, rtol=ATOL)


def test_kernel_layout_entrypoint_broadcasts_scalar():
    """decode_attention_bkgd itself accepts scalar and (B,) alike."""
    B, KV, G, hd, Smax = 2, 2, 2, 16, 128
    key = jax.random.PRNGKey(11)
    q = jax.random.normal(key, (B, KV, G, hd), jnp.float32)
    kc = jax.random.normal(jax.random.fold_in(key, 1), (B, KV, Smax, hd))
    vc = jax.random.normal(jax.random.fold_in(key, 2), (B, KV, Smax, hd))
    out_s = decode_attention_bkgd(q, kc, vc, 31, block_k=64, interpret=True)
    out_v = decode_attention_bkgd(q, kc, vc, jnp.full((B,), 31, jnp.int32),
                                  block_k=64, interpret=True)
    np.testing.assert_allclose(out_s, out_v, atol=ATOL, rtol=ATOL)


def test_ragged_smax_falls_back_to_ref_exactly():
    """Smax not divisible by the block: the wrapper must dispatch to the
    reference (bit-exact), never a mis-tiled kernel."""
    B, Smax, H, KV, hd = 2, 96, 4, 2, 16
    q, kc, vc = _case(13, B, Smax, H, KV, hd)
    index = jnp.asarray([5, 200], jnp.int32)
    out = ops.decode_attention(q, kc, vc, index, block_k=64, interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, index)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


# ------------------------------------------------------- ring-scatter write


def _check_ring_update(B, Smax, KV, hd, seed):
    key = jax.random.PRNGKey(seed)
    cache = jax.random.normal(key, (B, Smax, KV, hd), jnp.float32)
    new = jax.random.normal(jax.random.fold_in(key, 1), (B, KV, hd))
    slot = jnp.asarray(
        np.random.default_rng(seed).integers(0, Smax, size=B), jnp.int32)
    out = cache_ring_update_bs(cache, new, slot, interpret=True)
    want = ref.cache_ring_update_ref(cache, new, slot)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


@pytest.mark.parametrize("B,Smax,KV,hd", [
    (1, 8, 1, 8), (2, 24, 2, 8), (4, 128, 2, 32), (3, 24, 1, 32),
])
def test_ring_update_matches_jnp_scatter_grid(B, Smax, KV, hd):
    _check_ring_update(B, Smax, KV, hd, seed=B * Smax + KV + hd)


if st is not None:
    @settings(max_examples=16, deadline=None)
    @given(
        B=st.sampled_from([1, 2, 4]),
        Smax=st.sampled_from([8, 24, 128]),
        KV=st.sampled_from([1, 2]),
        hd=st.sampled_from([8, 32]),
        seed=st.integers(0, 2**16),
    )
    def test_ring_update_matches_jnp_scatter_fuzz(B, Smax, KV, hd, seed):
        _check_ring_update(B, Smax, KV, hd, seed)


def test_ring_update_preserves_untouched_rows_bit_exact():
    B, Smax, KV, hd = 3, 16, 2, 8
    key = jax.random.PRNGKey(17)
    cache = jax.random.normal(key, (B, Smax, KV, hd), jnp.float32)
    new = jax.random.normal(jax.random.fold_in(key, 1), (B, KV, hd))
    slot = jnp.asarray([0, 7, 15], jnp.int32)
    out = np.asarray(ops.cache_ring_update(cache, new, slot, interpret=True))
    for b, s in enumerate([0, 7, 15]):
        np.testing.assert_array_equal(out[b, s], np.asarray(new)[b])
        untouched = np.delete(np.asarray(cache)[b], s, axis=0)
        np.testing.assert_array_equal(np.delete(out[b], s, axis=0), untouched)


def test_ring_update_casts_to_cache_dtype():
    cache = jnp.zeros((2, 8, 2, 8), jnp.bfloat16)
    new = jnp.full((2, 2, 8), 1.5, jnp.float32)
    out = ops.cache_ring_update(cache, new, jnp.asarray([1, 2]),
                                interpret=True)
    assert out.dtype == jnp.bfloat16
    assert float(out[0, 1, 0, 0]) == 1.5 and float(out[1, 2, 1, 3]) == 1.5
