"""Property suites for the fleet's economics and the priority-lane queue.

Hypothesis-driven invariants (skipped where hypothesis isn't installed —
CI's requirements-dev.txt has it):

* ``FleetPlan.cost_of`` is monotone non-decreasing in fleet size, prices
  every replica past the reserved pool at exactly the (possibly market)
  spot rate, and decomposes as the sum of ``price_of`` over ids — the
  profile the router sees and the cost the optimizer minimizes can never
  disagree about what a replica costs.
* ``SpotMarket`` prices are always >= floor (positive), deterministic in
  (seed, tick), and independent of query order — two planners reading the
  same market in different orders see the same path.
* ``FCFSScheduler`` is first-come-first-served WITHIN each lane under any
  interleaving of submits/pops, never admits batch work while gated, and
  ``pop``/``peek`` always agree on the head.
"""
import numpy as np
import pytest

hyp = pytest.importorskip("hypothesis")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.serving.profiles import FleetPlan, SpotMarket  # noqa: E402
from repro.serving.scheduler import (  # noqa: E402
    FCFSScheduler, Request, TIERS,
)

REGION_POOLS = [(), ("na",), ("na", "apac"), ("eu", "sa", "au")]

plans = st.builds(
    FleetPlan,
    reserved=st.integers(0, 5),
    cost_on_demand=st.floats(0.1, 10.0, allow_nan=False),
    cost_preemptible=st.floats(0.01, 5.0, allow_nan=False),
    regions=st.sampled_from(REGION_POOLS),
    market=st.one_of(st.none(),
                     st.builds(SpotMarket, seed=st.integers(0, 99))),
)


@given(plan=plans, n=st.integers(0, 12),
       tick=st.one_of(st.none(), st.integers(0, 60)))
@settings(max_examples=80, deadline=None)
def test_cost_of_monotone_and_marginal_priced_at_spot(plan, n, tick):
    assert plan.cost_of(n, tick) <= plan.cost_of(n + 1, tick)
    # the marginal replica past the reserved pool costs exactly the spot
    # rate at that tick; inside the pool, exactly the on-demand rate
    marginal = plan.cost_of(n + 1, tick) - plan.cost_of(n, tick)
    expected = (plan.cost_on_demand if n < plan.reserved
                else plan.spot_price(tick))
    assert marginal == pytest.approx(expected)


@given(plan=plans, n=st.integers(0, 12),
       tick=st.one_of(st.none(), st.integers(0, 60)))
@settings(max_examples=80, deadline=None)
def test_cost_of_decomposes_as_price_of_and_matches_profiles(plan, n, tick):
    assert plan.cost_of(n, tick) == pytest.approx(
        sum(plan.price_of(i, tick) for i in range(n)))
    for i in range(n):
        prof = plan.profile_for(i)
        # profile_for and price_of agree on which pool the id is in …
        assert prof.preemptible == (i >= plan.reserved)
        # … and the static profile rate is price_of at the catalog constant
        if not prof.preemptible:
            assert plan.price_of(i, tick) == prof.cost_per_tick
        else:
            assert plan.price_of(i, None) == prof.cost_per_tick
        assert prof.region == plan.region_of(i)


@given(seed=st.integers(0, 200), data=st.data())
@settings(max_examples=40, deadline=None)
def test_spot_market_positive_and_seed_deterministic(seed, data):
    a, b = SpotMarket(seed=seed), SpotMarket(seed=seed)
    order = data.draw(st.permutations(list(range(30))))
    shuffled = {t: b.price(t) for t in order}        # any query order …
    for t in range(30):
        p = a.price(t)                               # … vs sequential
        assert p >= a.floor > 0.0
        assert p == shuffled[t]


@given(seed=st.integers(0, 50), ticks=st.integers(1, 40))
@settings(max_examples=40, deadline=None)
def test_spot_market_prices_is_the_price_path(seed, ticks):
    m = SpotMarket(seed=seed)
    assert m.prices(ticks) == [m.price(t) for t in range(ticks)]


# -------------------------------------------------------- scheduler lanes


def _req(rid: int, tier: str) -> Request:
    return Request(rid=rid, prompt=np.array([3, 4, 5], np.int32),
                   gen_len=2, tier=tier)


ops = st.lists(
    st.one_of(
        st.tuples(st.just("submit"), st.sampled_from(TIERS)),
        st.tuples(st.just("pop"), st.none()),
        st.tuples(st.just("gate"), st.booleans()),
    ),
    min_size=1, max_size=60)


@given(ops=ops)
@settings(max_examples=100, deadline=None)
def test_scheduler_fifo_within_lane_and_gate_blocks_batch(ops):
    sched = FCFSScheduler()
    submitted = {t: [] for t in TIERS}               # per-lane submit order
    popped = {t: [] for t in TIERS}
    rid = 0
    for op, arg in ops:
        if op == "submit":
            sched.submit(_req(rid, arg))
            submitted[arg].append(rid)
            rid += 1
        elif op == "gate":
            sched.batch_gated = arg
        elif sched:                                  # pop iff admissible
            head = sched.peek()
            r = sched.pop()
            assert r is head                         # pop/peek agree
            assert not (sched.batch_gated and r.tier == "batch")
            popped[r.tier].append(r.rid)
    for t in TIERS:
        # what left each lane is a prefix of what entered it, in order
        assert popped[t] == submitted[t][:len(popped[t])]
    # gated batch backlog is invisible to admission but still counted
    sched.batch_gated = True
    leftover_batch = sched.lane_depth("batch")
    while sched:
        assert sched.pop().tier != "batch"
    assert sched.lane_depth("batch") == leftover_batch
    assert sched.depth == leftover_batch
