"""The closed learning loop: trace recording/replay + five regressions.

Satellite regressions — each verified FAILING on the pre-fix src:

 1. ``PredictiveAllocator._pending_action`` was only assigned on the DQN
    path, so the first planner-fallback ``learn()`` died on AttributeError
    (and a later fallback credited a STALE DQN action).
 2. The DQN train step ran every forward with ``training=False``:
    BatchNorm running stats were never written, ``agent.bn_state`` stayed
    frozen at init forever.
 3. ``train.fit`` silently performed ZERO optimizer steps whenever the
    dataset was smaller than ``batch_size`` (the per-epoch range was empty).
 4. ``WorkloadForecaster.update`` gated first-observation seeding on
    truthiness (``self.daily[tod] or value``), so a legitimately observed
    0.0 load RESET the seasonal EWMA instead of being decayed toward.
 5. ``run_closed_loop`` recorded ``rps = arrivals-per-tick`` (a count);
    the forecaster/perf-model consume requests per virtual second — the
    two only coincide when ``steps_per_tick * tick_s == 1.0``.

Tentpole coverage: TraceRecorder JSONL round-trip, trace → StreamBuilder /
supervised-dataset / replay-transition shapes, offline pretraining, the
live ``alloc.learn`` wiring in the loop tick, and the hybrid envelope's
planner fallback under an infeasible SLO.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.allocation.allocator import AllocatorConfig, PredictiveAllocator
from repro.core.allocation.forecaster import WorkloadForecaster
from repro.core.allocation.rl import ACTIONS, DQNAgent, DQNConfig
from repro.core.dnn.features import deploy_vector
from repro.core.dnn.model import DNNConfig, MultiStreamDNN
from repro.core.dnn.train import fit
from repro.core.dnn.traces import (
    TraceRecorder, action_index, pretrain_on_trace, replay_streams,
    supervised_dataset, transitions,
)
from repro.core.scaling.scaler import ScalingConstraints
from repro.serving.closed_loop import LoopConfig, run_closed_loop
from repro.sim.serving import WorkloadSpec

from conftest import TINY_CFGS

CFG = TINY_CFGS["dense"]
SPEC = WorkloadSpec(prompt_len=8, gen_len=4)

DEPLOY = deploy_vector(model_params_b=1.0, family="dense", mesh_model=1,
                       mesh_data=1, region_idx=0, slo_ms=200.0,
                       cost_weight=0.5)

# a small DNN keeps every jit in this file cheap
SMALL_DNN = DNNConfig(window=8)


def _tick_rec(tick, *, rps=1.0, lat=100.0, util=0.5, delta=0, cost=1.0):
    return {"tick": tick, "rps": rps, "flop_util": util, "hbm_util": util,
            "ici_util": 0.0, "mem_frac": util, "queue_depth": 0.0,
            "replicas_frac": 0.25, "latency_p50": lat, "latency_p95": lat,
            "throughput": rps, "error_rate": 0.0, "transport_ms": 0.0,
            "action_delta": delta, "cost_per_tick": cost}


def _trace(n=8):
    return [_tick_rec(t, rps=1.0 + t, lat=80.0 + 10 * t, util=0.3 + 0.05 * t,
                      delta=(1 if t == 2 else 0), cost=1.0 + (t >= 3))
            for t in range(n)]


def _allocator(perf_model, *, mode="hybrid", max_replicas=4, slo_ms=200.0):
    return PredictiveAllocator(
        perf_model,
        ScalingConstraints(min_replicas=1, max_replicas=max_replicas,
                           slo_ms=slo_ms),
        DEPLOY, cfg=AllocatorConfig(mode=mode), dnn_cfg=SMALL_DNN, seed=0)


# ---------------------------------------------------------------------------
# satellite regressions
# ---------------------------------------------------------------------------


def test_planner_fallback_defines_pending_action():
    """Regression 1: when the hybrid DQN path falls through its envelope
    (here: SLO infeasible and no scale-up in range) learn() must credit the
    planner's actuated delta, not blow up on a never-assigned attribute
    (pre-fix: AttributeError on the first learn after a fallback)."""
    alloc = _allocator(lambda r, rps: (10_000.0, 1.0), max_replicas=1,
                       slo_ms=100.0)
    rec = _tick_rec(0)
    alloc.observe(rec)
    d = alloc.decide(rec)
    assert not d.reason.startswith("dqn")       # envelope fell through
    assert alloc._pending_action == action_index(d.delta)
    assert alloc.learn(rec, cost_per_tick=1.0) is None   # first: primes only
    alloc.observe(rec)
    alloc.decide(rec)
    alloc.learn(rec, cost_per_tick=1.0)          # pre-fix: AttributeError


def test_hybrid_defers_to_planner_when_slo_infeasible():
    """Envelope regression: under an infeasible spike NO action meets the
    SLO, and the planner's max-headroom response must win — the DQN must
    not get to actuate a smaller scale-up just because its delta is
    positive (pre-fix: any q-preferred scale-up was accepted)."""
    alloc = _allocator(lambda r, rps: (10_000.0, 1.0), max_replicas=4,
                       slo_ms=100.0)
    rec = _tick_rec(0, rps=50.0)
    alloc.observe(rec)
    d = alloc.decide(rec)
    assert not d.reason.startswith("dqn")
    assert d.target_replicas == 4                # the planner's max headroom


def test_learn_before_any_decide_is_a_noop():
    alloc = _allocator(lambda r, rps: (50.0, 0.5))
    assert alloc.learn(_tick_rec(0), cost_per_tick=1.0) is None


def test_dqn_training_updates_batchnorm_state():
    """Regression 2: the gradient pass now runs in training mode, so the
    deploy-stream BatchNorm running stats track the replayed data (pre-fix
    every forward was training=False and bn_state never moved)."""
    agent = DQNAgent(SMALL_DNN, DQNConfig(warmup=4, train_every=1,
                                          batch_size=4), seed=0)
    count0 = float(agent.bn_state["bn1"]["count"])
    mean0 = np.asarray(agent.bn_state["bn1"]["mean"]).copy()
    rng = np.random.default_rng(0)
    snaps = replay_streams(_trace(10), DEPLOY + 0.5, window=SMALL_DNN.window)
    losses = [agent.observe(snaps[t], int(rng.integers(len(ACTIONS))),
                            1.0, snaps[t + 1]) for t in range(9)]
    assert any(l is not None for l in losses)
    assert float(agent.bn_state["bn1"]["count"]) > count0
    assert not np.allclose(np.asarray(agent.bn_state["bn1"]["mean"]), mean0)


def test_fit_takes_steps_on_datasets_smaller_than_batch():
    """Regression 3: n=7 < batch_size=64 must still take one full-dataset
    step per epoch (pre-fix: zero steps, params returned unchanged)."""
    ds = supervised_dataset(_trace(8), DEPLOY, window=SMALL_DNN.window)
    assert len(ds["alloc_target"]) == 7
    params, state = MultiStreamDNN.init(__import__("jax").random.PRNGKey(0),
                                        SMALL_DNN)
    before = np.asarray(params["alloc"]["w"]).copy()
    params, state, losses = fit(params, state, ds, epochs=2, batch_size=64)
    assert len(losses) == 2                      # one step per epoch
    assert not np.allclose(np.asarray(params["alloc"]["w"]), before)


def test_forecaster_decays_toward_observed_zero_load():
    """Regression 4: an observed 0.0 is a real data point.  After seeing
    0.0 at a time-of-day slot, the next observation must be EWMA-decayed
    toward it (pre-fix: truthiness treated the stored 0.0 as 'unseen' and
    reset the profile to the new value)."""
    f = WorkloadForecaster(ticks_per_day=4, alpha=0.3)
    f.update(0.0)                                # tod 0, day 0
    for _ in range(3):
        f.update(5.0)                            # tod 1..3
    f.update(10.0)                               # tod 0 again
    assert f.daily[0] == pytest.approx(3.0)      # 0.3*10 + 0.7*0, not 10.0


def test_forecaster_level_survives_zero_starts():
    f = WorkloadForecaster(ticks_per_day=4, alpha=0.3)
    for v in (0.0, 0.0, 10.0):
        f.update(v)
    assert f.level == pytest.approx(3.0)         # pre-fix: reset to 10.0


def test_recorded_rps_is_per_virtual_second():
    """Regression 5: with steps_per_tick=5 and tick_s=0.4 a tick spans 2.0
    virtual seconds — the recorded rate must be arrivals / 2.0 (pre-fix it
    was the raw arrival count, 2x the true rate at this shape)."""
    lc = dataclasses.replace(LoopConfig(), steps_per_tick=5, tick_s=0.4,
                             max_replicas=2)
    rec = TraceRecorder()
    router, logs = run_closed_loop(CFG, autoscale=True, ticks=6, seed=0,
                                   lc=lc, spec=SPEC, recorder=rec)
    router.close()
    assert sum(r["arrivals"] for r in rec.records) > 0
    for r in rec.records:
        assert r["rps"] == pytest.approx(r["arrivals"] / 2.0)


# ---------------------------------------------------------------------------
# tentpole: trace recording, replay, offline training, live wiring
# ---------------------------------------------------------------------------


def test_trace_recorder_roundtrip(tmp_path):
    rec = TraceRecorder()
    for r in _trace(5):
        rec.record(r)
    p = tmp_path / "trace.jsonl"
    rec.save(p)
    assert len(TraceRecorder.load(p)) == 5
    assert TraceRecorder.load(p).records == rec.records


def test_recorder_copies_records():
    rec = TraceRecorder()
    r = _tick_rec(0)
    rec.record(r)
    r["rps"] = 99.0                              # later mutation by the loop
    assert rec.records[0]["rps"] == 1.0


def test_replay_streams_match_live_shapes():
    snaps = replay_streams(_trace(6), DEPLOY, window=SMALL_DNN.window)
    assert len(snaps) == 6
    for s in snaps:
        assert s["resource"].shape == (1, SMALL_DNN.window,
                                       SMALL_DNN.n_resource_features)
        assert s["perf"].shape == (1, SMALL_DNN.window,
                                   SMALL_DNN.n_perf_features)
        assert s["deploy"].shape == (1, SMALL_DNN.n_deploy_features)


def test_supervised_dataset_targets_next_tick():
    recs = _trace(6)
    ds = supervised_dataset(recs, DEPLOY, window=SMALL_DNN.window)
    assert len(ds["alloc_target"]) == 5
    # row t's target is tick t+1's realized utilization
    assert ds["alloc_target"][0][0] == pytest.approx(recs[1]["flop_util"])
    assert ds["strategy_target"].dtype == np.int32
    with pytest.raises(ValueError):
        supervised_dataset(recs[:1], DEPLOY)


def test_transitions_credit_recorded_action_with_next_reward():
    recs = _trace(6)
    trans = transitions(recs, DEPLOY, window=SMALL_DNN.window)
    assert len(trans) == 5
    s, a, r, s2, done = trans[2]                 # the tick with delta=+1
    assert a == ACTIONS.index(1)
    assert not done and trans[-1][4]             # only the last is terminal
    # the reward is computed from tick t+1's realized metrics: higher next-
    # tick utilization at equal latency/cost ⇒ strictly better reward
    hi = [dict(x, flop_util=0.9) for x in recs]
    assert transitions(hi, DEPLOY, window=SMALL_DNN.window)[2][2] > r


def test_action_index_snaps_to_nearest_delta():
    assert ACTIONS[action_index(0)] == 0
    assert ACTIONS[action_index(3)] in (2, 4)
    assert ACTIONS[action_index(-7)] == -4


def test_pretrain_on_trace_trains_all_three_phases():
    alloc = _allocator(lambda r, rps: (50.0, 0.5))
    out = pretrain_on_trace(alloc, _trace(8), epochs=2, imitation_epochs=2,
                            dqn_steps=3)
    assert out["transitions"] == 7
    assert len(out["supervised"]) == 2 and len(out["dqn"]) == 3
    assert out["imitation"][-1] < out["imitation"][0]    # CE decreases
    # a pretrained agent is warm: online learning no longer waits for the
    # full cold-start warmup fill
    assert alloc.agent.cfg.warmup <= alloc.agent.buffer.n
    # and the warmed StreamBuilder has seen the trace
    assert len(alloc.streams.res_hist) == 8


def test_closed_loop_live_learning_takes_train_steps():
    """The tentpole wiring: run_closed_loop calls alloc.learn each tick, so
    with a warm (low-warmup) agent the TickLog carries real DQN losses."""
    def prime(alloc):
        alloc.agent.cfg = dataclasses.replace(
            alloc.agent.cfg, warmup=2, train_every=1, batch_size=2)

    lc = dataclasses.replace(LoopConfig(), max_replicas=2,
                             alloc_mode="planner")
    router, logs = run_closed_loop(CFG, autoscale=True, ticks=6, seed=0,
                                   lc=lc, spec=SPEC, prime_allocator=prime)
    router.close()
    assert any(t.learn_loss is not None for t in logs)


def test_closed_loop_learn_flag_off_means_no_updates():
    lc = dataclasses.replace(LoopConfig(), max_replicas=2, learn=False)
    router, logs = run_closed_loop(CFG, autoscale=True, ticks=4, seed=0,
                                   lc=lc, spec=SPEC)
    router.close()
    assert all(t.learn_loss is None for t in logs)


def test_chaos_hook_sees_control_plane_state():
    seen = []

    def hook(tick, router, collector):
        seen.append((tick, router.replica_count))

    lc = dataclasses.replace(LoopConfig(), max_replicas=2)
    router, logs = run_closed_loop(CFG, autoscale=True, ticks=4, seed=0,
                                   lc=lc, spec=SPEC, chaos_hook=hook)
    router.close()
    assert [t for t, _ in seen] == [0, 1, 2, 3]
    assert all(n >= 1 for _, n in seen)


def test_recorded_trace_pretrains_and_redeploys_hybrid():
    """End-to-end smoke of the loop the benchmark A/Bs: record a planner
    trace on the live data plane, offline-train on it, then run the learned
    policy as the hybrid scaler on the same seed."""
    lc = dataclasses.replace(LoopConfig(), max_replicas=2)
    rec = TraceRecorder()
    router, _ = run_closed_loop(CFG, autoscale=True, ticks=6, seed=0, lc=lc,
                                spec=SPEC, recorder=rec)
    router.close()
    assert len(rec) == 6

    def prime(alloc):
        pretrain_on_trace(alloc, rec.records, epochs=1, imitation_epochs=1,
                          dqn_steps=2)

    router, logs = run_closed_loop(
        CFG, autoscale=True, ticks=4, seed=0,
        lc=dataclasses.replace(lc, alloc_mode="hybrid"),
        spec=SPEC, prime_allocator=prime)
    router.close()
    assert len(logs) == 4
    assert all(1 <= t.replicas <= 2 for t in logs)
