"""Simulator: cluster cost/provisioning, queueing serving model properties,
workload traces, roofline DB grounding (reads the real dry-run artifacts).
"""
import math
from pathlib import Path

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # collection must degrade to skips, not errors
from hypothesis import given, settings, strategies as st

from repro.models import SHAPES
from repro.sim import (
    Cluster, RooflineDB, ServiceProfile, ServingModel, TraceConfig,
    WorkloadSpec, generate_trace, mmc_wait_s,
)
from repro.sim.workload import REGIONS

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "results" / "dryrun"


# ---------------------------------------------------------------- cluster

def test_cluster_scale_and_cost():
    c = Cluster(provider="gcp", region="na", chips_per_replica=16, tick_s=3600)
    c.scale_to(4)
    assert c.total_replicas() == 4
    assert c.ready_replicas() == 0            # provisioning delay
    for _ in range(100):
        c.advance()
    assert c.ready_replicas() == 4
    # 4 replicas × 16 chips × $1.20/hr × 100 h
    assert c.spend_usd == pytest.approx(4 * 16 * 1.20 * 100, rel=1e-6)


def test_cluster_scale_down_immediate():
    c = Cluster()
    c.scale_to(5)
    c.scale_to(2)
    assert c.total_replicas() == 2


def test_cluster_failures_trigger_replacement():
    c = Cluster(seed=1)
    c.scale_to(8)
    c.tick = 10**6                            # everyone ready
    before = {r.id for r in c.replicas}
    for _ in range(50):
        c.advance(fail_prob=0.05)
    after = {r.id for r in c.replicas}
    assert after != before                    # some replaced
    assert c.total_replicas() == 8            # capacity restored


def test_region_cost_multipliers():
    na = Cluster(region="na"); na.scale_to(1); na.advance()
    au = Cluster(region="au"); au.scale_to(1); au.advance()
    assert au.spend_usd > na.spend_usd


# ---------------------------------------------------------------- queueing

@settings(max_examples=30, deadline=None)
@given(lam=st.floats(0.1, 50.0), mu=st.floats(0.1, 10.0),
       c=st.integers(1, 200))
def test_mmc_wait_nonnegative(lam, mu, c):
    w = mmc_wait_s(lam, mu, c)
    assert w >= 0.0 or math.isinf(w)
    if lam >= c * mu:
        assert math.isinf(w)


def test_mmc_wait_monotone_in_servers():
    waits = [mmc_wait_s(8.0, 1.0, c) for c in (9, 12, 16, 32)]
    assert all(a >= b - 1e-12 for a, b in zip(waits, waits[1:]))


def test_mmc_wait_monotone_in_load():
    waits = [mmc_wait_s(lam, 1.0, 10) for lam in (2.0, 5.0, 8.0, 9.5)]
    assert all(a <= b + 1e-12 for a, b in zip(waits, waits[1:]))


def test_mmc_large_c_approximation_continuous():
    """The c≥120 normal approximation must not jump discontinuously."""
    w119 = mmc_wait_s(100.0, 1.0, 119)
    w121 = mmc_wait_s(100.0, 1.0, 121)
    assert abs(w119 - w121) < max(w119, 1e-6) * 2.0


# ---------------------------------------------------------------- serving

@pytest.fixture(scope="module")
def profile():
    db = RooflineDB(DRYRUN_DIR)
    return ServiceProfile.from_db(db, "qwen2.5-3b")


def test_profile_from_dryrun_is_measured(profile):
    db = RooflineDB(DRYRUN_DIR)
    assert db.terms("qwen2.5-3b", "decode_32k").measured
    assert profile.decode_step_s > 0
    assert profile.slots == SHAPES["decode_32k"].global_batch // 16


def test_latency_decreases_with_replicas(profile):
    m = ServingModel(profile, WorkloadSpec(prompt_len=512, gen_len=64))
    lats = [m.latency_util(r, 5.0)[0] for r in (1, 2, 4, 8)]
    assert all(a >= b - 1e-9 for a, b in zip(lats, lats[1:]))


def test_utilization_increases_with_load(profile):
    m = ServingModel(profile, WorkloadSpec())
    utils = [m.latency_util(4, rps)[1] for rps in (0.5, 1.0, 2.0)]
    assert all(a <= b for a, b in zip(utils, utils[1:]))
    assert all(0 <= u <= 1 for u in utils)


def test_overload_produces_errors_and_queue(profile):
    m = ServingModel(profile, WorkloadSpec(prompt_len=512, gen_len=64),
                     tick_s=60.0, seed=0)
    cap = profile.requests_per_s(WorkloadSpec(prompt_len=512, gen_len=64))
    r = None
    for _ in range(8):
        r = m.tick(replicas=1, rps=cap * 5.0)     # 5× overload
    assert r.errors > 0
    assert r.queue_depth >= 0
    assert r.utilization > 0.9


def test_underload_is_healthy(profile):
    m = ServingModel(profile, WorkloadSpec(prompt_len=512, gen_len=64),
                     tick_s=60.0, seed=0)
    cap = profile.requests_per_s(WorkloadSpec(prompt_len=512, gen_len=64))
    r = m.tick(replicas=8, rps=cap * 8 * 0.3)
    assert r.errors == 0
    assert 0.1 < r.utilization < 0.6
    assert np.isfinite(r.latency_ms_samples).all()


# ---------------------------------------------------------------- traces

def test_trace_positive_and_diurnal():
    cfg = TraceConfig(base_rps=100.0, ticks_per_day=96, seed=3)
    rps = generate_trace(cfg, 96 * 7)
    assert (rps >= 1.0).all()
    # diurnal structure: within-day range is a large fraction of the mean
    day = rps[:96]
    assert (day.max() - day.min()) / day.mean() > 0.4


def test_trace_weekend_dip():
    cfg = TraceConfig(base_rps=100.0, ticks_per_day=24, weekly_amp=0.3,
                      noise_cv=0.01, spike_prob=0.0, seed=4)
    rps = generate_trace(cfg, 24 * 7)
    weekday = rps[:24 * 5].mean()
    weekend = rps[24 * 5:].mean()
    assert weekend < weekday


def test_trace_regions_differ_in_phase():
    n = 96 * 2
    na = generate_trace(TraceConfig(region="na", ticks_per_day=96,
                                    noise_cv=0.0, spike_prob=0.0), n)
    apac = generate_trace(TraceConfig(region="apac", ticks_per_day=96,
                                      noise_cv=0.0, spike_prob=0.0), n)
    assert int(np.argmax(na[:96])) != int(np.argmax(apac[:96]))
    assert set(REGIONS) == {"na", "eu", "apac", "sa", "au"}


def test_trace_spikes_present():
    cfg = TraceConfig(spike_prob=0.05, noise_cv=0.0, seed=5)
    rps = generate_trace(cfg, 500)
    base = generate_trace(TraceConfig(spike_prob=0.0, noise_cv=0.0, seed=5), 500)
    assert rps.max() > 1.5 * base.max()


# ---------------------------------------------------------------- roofline db

def test_roofline_db_reads_all_measured_cells():
    db = RooflineDB(DRYRUN_DIR)
    from repro.configs import ARCH_IDS, get_config
    from repro.models import applicable_shapes
    n_measured = 0
    for arch in ARCH_IDS:
        for shape in applicable_shapes(get_config(arch)):
            t = db.terms(arch, shape)
            assert t.step_time > 0
            assert t.bottleneck in ("compute", "memory", "collective")
            assert t.step_time == max(t.t_compute, t.t_memory, t.t_collective)
            n_measured += t.measured
    assert n_measured == 33                    # every assigned cell compiled


def test_roofline_analytic_fallback():
    db = RooflineDB("/nonexistent")
    t = db.terms("qwen2.5-3b", "train_4k")
    assert not t.measured
    assert t.step_time > 0


def test_roofline_terms_scale_with_hardware_constants():
    from repro.sim.roofline_db import HBM_BW, ICI_BW, PEAK_FLOPS
    db = RooflineDB(DRYRUN_DIR)
    t = db.terms("qwen2-72b", "train_4k")
    assert t.t_compute == pytest.approx(t.flops / PEAK_FLOPS)
    assert t.t_memory == pytest.approx(t.bytes / HBM_BW)
    assert t.t_collective == pytest.approx(t.coll_bytes / ICI_BW)
