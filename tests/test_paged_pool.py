"""Paged KV cache: block-table pool, refcounted prefix sharing, COW forks.

Four layers, mirroring the PR's acceptance bar:

* kernel parity — the block-gather decode kernel and the table-routed
  cache scatter against their jnp oracles AND against the dense ring
  kernels laid out identically (the bit-identity basis);
* allocator unit tests — refcounts, prefix registry LRU, copy-on-write,
  capacity gating, and the ≥2× concurrent-in-flight claim at fixed HBM;
* engine equivalence — shared-prefix workloads produce token streams
  bit-identical to the dense pool on every model family, with staggered
  admission and mid-run preemption (router downscale), and refcounts
  return to zero after evacuate();
* this PR's satellite bugfix regressions — the closed loop's negative
  service-time capacity model, the collector's stale-report replay and
  unbounded retired-replica footprint, and Request's shared class-level
  SamplingParams default.
"""
import dataclasses
import functools
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving import (
    PagedSlotPool, Request, SamplingParams, ServingEngine, make_pool,
    paged_cache_spec,
)
from repro.serving.engine import EngineCore

from conftest import TINY_CFGS

MAX_SEQ = 24
BK = 4
FAMILIES = ["dense", "swa", "vlm", "moe", "hybrid"]


@functools.lru_cache(maxsize=None)
def core_for(family: str, use_pallas: bool = False) -> EngineCore:
    cfg = TINY_CFGS[family]
    if use_pallas:
        cfg = dataclasses.replace(cfg, use_pallas=True)
    return EngineCore(cfg, MAX_SEQ, seed=0)


def make_engine(family: str, *, slots=2, prefill_chunk=4, pool="dense",
                use_pallas=False, **kw) -> ServingEngine:
    core = core_for(family, use_pallas)
    return ServingEngine(core.cfg, slots=slots, max_seq=MAX_SEQ,
                         prefill_chunk=prefill_chunk, core=core, pool=pool,
                         **kw)


def shared_prefix_requests(family: str, n, *, prefix_len=8, prompt_len=11,
                           gen_len=3, seed=0):
    cfg = TINY_CFGS[family]
    rng = np.random.default_rng(seed)
    prefix = rng.integers(3, cfg.vocab, size=prefix_len).astype(np.int32)
    return [Request(rid=i,
                    prompt=np.concatenate(
                        [prefix, rng.integers(3, cfg.vocab,
                                              size=prompt_len - prefix_len
                                              ).astype(np.int32)]),
                    gen_len=gen_len) for i in range(n)]


def run_staggered(eng, reqs, max_steps=600):
    """Submit one request per tick (staggered admission), run to drain."""
    done, now, i = [], 0.0, 0
    for _ in range(max_steps):
        if i < len(reqs):
            eng.submit(reqs[i], now=now)
            i += 1
        now += 1.0
        done.extend(eng.step(now=now))
        if len(done) >= len(reqs) and eng.idle:
            return {r.rid: tuple(r.tokens_out) for r in done}
    raise AssertionError(f"stalled at {len(done)}/{len(reqs)}")


# ------------------------------------------------------------ kernel parity


@pytest.mark.kernels
def test_paged_decode_attention_matches_ref():
    from repro.kernels import ops, ref

    B, H, KV, hd, NB = 3, 4, 2, 32, 13
    nk, bk = 4, 8
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, 1, H, hd), jnp.float32)
    kc = jax.random.normal(jax.random.fold_in(key, 1), (NB, bk, KV, hd))
    vc = jax.random.normal(jax.random.fold_in(key, 2), (NB, bk, KV, hd))
    rng = np.random.default_rng(3)
    # each row walks a distinct permutation of physical blocks
    tbl = np.stack([rng.permutation(NB)[:nk] for _ in range(B)]).astype(np.int32)
    for index in ([0, 7, 31], [31, 12, 1], [5, 5, 5]):
        idx = np.asarray(index, np.int32)
        out = ops.decode_attention_paged(q, kc, vc, tbl, idx, interpret=True)
        want = ref.decode_attention_paged_ref(q, kc, vc, tbl, idx)
        np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


@pytest.mark.kernels
def test_paged_decode_attention_matches_dense_kernel_bitwise():
    """The bit-identity basis: lay a dense (B, Smax, KV, hd) ring into the
    block pool under an identity table — the paged kernel must reproduce
    the dense vector-index kernel's output EXACTLY (same flash recurrence,
    same block schedule, only the address computation differs)."""
    from repro.kernels import ops

    B, H, KV, hd, Smax, bk = 2, 4, 2, 32, 64, 8
    nk = Smax // bk
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (B, 1, H, hd), jnp.float32)
    kc = jax.random.normal(jax.random.fold_in(key, 1), (B, Smax, KV, hd))
    vc = jax.random.normal(jax.random.fold_in(key, 2), (B, Smax, KV, hd))
    # identity layout: block b*nk + j holds row b's tokens [j*bk, (j+1)*bk)
    pool_k = kc.reshape(B * nk, bk, KV, hd)
    pool_v = vc.reshape(B * nk, bk, KV, hd)
    tbl = np.arange(B * nk, dtype=np.int32).reshape(B, nk)
    index = np.asarray([Smax - 1, 23], np.int32)
    dense = ops.decode_attention(q, kc, vc, index, block_k=bk, interpret=True)
    paged = ops.decode_attention_paged(q, pool_k, pool_v, tbl, index,
                                       interpret=True)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(paged))


@pytest.mark.kernels
def test_paged_cache_update_matches_ref():
    from repro.kernels import ops, ref

    NB, bk, KV, hd, B = 9, 8, 2, 32, 4
    key = jax.random.PRNGKey(2)
    cache = jax.random.normal(key, (NB, bk, KV, hd), jnp.float32)
    new = jax.random.normal(jax.random.fold_in(key, 1), (B, KV, hd))
    blk = np.asarray([1, 4, 7, 2], np.int32)
    off = np.asarray([0, 3, 7, 5], np.int32)
    got = ops.cache_paged_update(cache, new, blk, off, interpret=True)
    want = ref.cache_paged_update_ref(cache, new, blk, off)
    np.testing.assert_allclose(got, want, atol=0, rtol=0)
    # untouched blocks bit-identical to the input
    mask = np.ones(NB, bool)
    mask[blk] = False
    np.testing.assert_array_equal(np.asarray(got)[mask],
                                  np.asarray(cache)[mask])


# ------------------------------------------------------------ allocator


def test_paged_cache_spec_layout():
    cfg = TINY_CFGS["dense"]
    spec = paged_cache_spec(cfg, 4, MAX_SEQ, block_size=BK, num_blocks=25)
    (shape, dtype, axes) = spec["layers"]["k"]
    assert shape == (cfg.n_layers, 25, BK, cfg.n_kv_heads,
                     cfg.d_model // cfg.n_heads)
    assert axes == ("layers", "cache_blocks", None, "kv_heads", None)
    assert spec["block_tbl"][0] == (4, MAX_SEQ // BK)
    assert spec["index"][0] == (4,)


def test_admit_release_returns_refcounts_to_zero():
    pool = PagedSlotPool(TINY_CFGS["dense"], 2, MAX_SEQ, block_size=BK)
    free0 = len(pool.free[0])
    prompt = np.arange(3, 14, dtype=np.int32)         # 11 tokens
    assert pool.can_admit(0, prompt, 3)
    h = pool.admit_slot(0, prompt, 3)
    assert h == 0                                     # cold registry
    need = pool.blocks_needed(11 + 3)
    assert len(pool.slot_blocks[0]) == need
    assert all(pool.refcount[b] == 1 for b in pool.slot_blocks[0])
    pool.release(0)
    assert (pool.refcount == 0).all()
    assert len(pool.free[0]) == free0
    # the freed row parks on the trash block
    assert (pool.tables[0] == pool.trash[0]).all()


def test_prefix_sharing_and_registry_refcounts():
    pool = PagedSlotPool(TINY_CFGS["dense"], 2, MAX_SEQ, block_size=BK)
    prompt = np.arange(3, 14, dtype=np.int32)         # 11 tokens, 2 whole blocks
    pool.admit_slot(0, prompt, 3)
    # prefill published both whole prompt blocks ((P-1)//bk = 2)
    for j in range(2):
        pool.register_block(0, j, prompt)
    # same prefix, different tail → 2 blocks resident
    prompt2 = np.concatenate([prompt[:8], np.asarray([60, 61, 62], np.int32)])
    h = pool.admit_slot(1, prompt2, 3)
    assert h == 2 * BK
    assert pool.n_prefix_hits == 1 and pool.tokens_shared == 2 * BK
    shared = pool.slot_blocks[1][:2]
    assert shared == pool.slot_blocks[0][:2]
    # 1 (slot 0) + 1 (slot 1) + 1 (registry)
    assert all(pool.refcount[b] == 3 for b in shared)
    pool.release(0)
    assert all(pool.refcount[b] == 2 for b in shared)   # survives release
    pool.release(1)
    assert all(pool.refcount[b] == 1 for b in shared)   # registry's ref
    pool.release_registry()
    assert (pool.refcount == 0).all()


def test_copy_on_write_fork_preserves_contents():
    pool = PagedSlotPool(TINY_CFGS["dense"], 2, MAX_SEQ, block_size=BK)
    prompt = np.arange(3, 14, dtype=np.int32)
    pool.admit_slot(0, prompt, 3)
    for j in range(2):
        pool.register_block(0, j, prompt)
    pool.admit_slot(1, prompt, 3)                     # maps blocks 0,1 shared
    blk = int(pool.tables[1, 0])
    # mark the shared block's contents so the copy is observable
    k = pool.cache["layers"]["k"]
    marked = k.at[:, blk].set(7.5)
    pool.cache = {**pool.cache,
                  "layers": {**pool.cache["layers"], "k": marked}}
    new = pool.ensure_private(1, 0)
    assert new != blk
    assert int(pool.tables[1, 0]) == new
    assert int(pool.tables[0, 0]) == blk              # slot 0 untouched
    k = pool.cache["layers"]["k"]
    np.testing.assert_array_equal(np.asarray(k[:, new]), np.asarray(k[:, blk]))
    assert pool.refcount[new] == 1
    # a block the slot owns privately is returned unchanged
    priv = int(pool.tables[0, 2])
    assert pool.ensure_private(0, 2) == priv


def test_registry_lru_reclaim_under_pressure():
    """A full pool evicts registry-only (refcount == 1) blocks LRU-first to
    admit new work — the prefix cache is a cache, not a leak."""
    cfg = TINY_CFGS["dense"]
    nk = MAX_SEQ // BK
    # room for exactly 2 full-length slots (+ trash)
    pool = PagedSlotPool(cfg, 2, MAX_SEQ, block_size=BK,
                         num_blocks=2 * nk + 1)
    long_a = np.arange(3, 3 + 20, dtype=np.int32)     # 20 tokens + 4 gen
    pool.admit_slot(0, long_a, 4)
    for j in range(4):
        pool.register_block(0, j, long_a)
    pool.release(0)                                   # registry holds 4 blocks
    assert sum(pool.refcount > 0) == 4
    long_b = np.arange(40, 60, dtype=np.int32)        # disjoint prompt
    assert pool.can_admit(0, long_b, 4)               # evictable counts
    pool.admit_slot(0, long_b, 4)
    pool.admit_slot(1, long_b[::-1].copy(), 4)        # forces full reclaim
    assert pool.n_prefix_hits == 0
    pool.release(0)
    pool.release(1)
    pool.release_registry()
    assert (pool.refcount == 0).all()


def test_admit_under_pressure_never_evicts_its_own_hit_blocks():
    """Regression: admit_slot reclaimed AFTER lookup_prefix but before
    taking references on the hit blocks, so under pool pressure _reclaim
    evicted the very blocks the admission was about to share — the private
    free.pop()s then handed the same physical block out again as a WRITABLE
    block in the same table row (and can_admit counted those hit blocks as
    evictable, promising capacity _reclaim could only deliver by corrupting
    the share)."""
    cfg = TINY_CFGS["dense"]
    max_seq, bk = 16, 4
    # 1 trash + 4 usable blocks: exactly one full-length admission
    pool = PagedSlotPool(cfg, 2, max_seq, block_size=bk, num_blocks=5)
    prompt_a = np.arange(3, 15, dtype=np.int32)       # 12 tokens = 3 blocks
    pool.admit_slot(0, prompt_a, 4)                   # 4 blocks (16 tokens)
    for j in range(2):                                # publish 2 prefix blocks
        pool.register_block(0, j, prompt_a)
    pool.release(0)                  # 2 registry-only blocks + 2 free
    pool.admit_slot(1, np.arange(40, 48, dtype=np.int32), 0)  # occupy the 2
    hit_blocks = list(pool.lookup_prefix(0, prompt_a)[1])
    assert len(hit_blocks) == 2 and not pool.free[0]
    # the only "evictable" blocks ARE the hit blocks: admission must refuse
    assert not pool.can_admit(0, prompt_a, 4)
    with pytest.raises(AssertionError, match="exhausted"):
        pool.admit_slot(0, prompt_a, 4)
    # the failed admission rolled back cleanly: registry refs intact, slot
    # row still parked on trash, slot 1 untouched
    assert all(pool.refcount[b] == 1 for b in hit_blocks)
    assert pool.lookup_prefix(0, prompt_a)[0] == 2
    assert not pool.slot_blocks[0]
    assert (pool.tables[0] == pool.trash[0]).all()
    assert all(pool.refcount[b] == 1 for b in pool.slot_blocks[1])
    # freeing slot 1 makes the same admission succeed with DISTINCT blocks
    pool.release(1)
    h = pool.admit_slot(0, prompt_a, 4)
    assert h == 2 * bk
    row = [int(b) for b in pool.tables[0]]
    assert len(set(row)) == len(row), row            # no double-mapped block


def test_reclaim_under_pinned_hits_evicts_other_registry_blocks():
    """With the hit blocks pinned, reclaim still evicts NON-hit registry
    blocks to make room — and a block this admission shares never transits
    the free list."""
    cfg = TINY_CFGS["dense"]
    max_seq, bk = 16, 4
    pool = PagedSlotPool(cfg, 1, max_seq, block_size=bk, num_blocks=5)
    prompt_a = np.arange(3, 15, dtype=np.int32)       # 12 tokens
    pool.admit_slot(0, prompt_a, 4)
    for j in range(3):                   # register all 3 whole prompt blocks
        pool.register_block(0, j, prompt_a)
    pool.release(0)                      # 3 registry-only + 1 free
    h = pool.admit_slot(0, prompt_a, 4)  # hit capped at (P-1)//bk = 2 blocks
    assert h == 2 * bk
    row = [int(b) for b in pool.tables[0]]
    assert len(set(row)) == len(row), row
    shared = pool.slot_blocks[0][:2]
    assert all(pool.refcount[b] == 2 for b in shared)   # slot + registry
    assert not (set(shared) & set(pool.free[0]))
    pool.release(0)
    pool.release_registry()
    assert (pool.refcount == 0).all()


def test_paged_pool_doubles_inflight_at_fixed_hbm():
    """The headline capacity claim: at the HBM budget that bounds the dense
    pool to 4 resident requests, prefix sharing holds 8 concurrently."""
    cfg = TINY_CFGS["dense"]
    max_seq, bk = 16, 4
    dense_blocks = 4 * (max_seq // bk)                # 4 dense slots' HBM
    pool = PagedSlotPool(cfg, 8, max_seq, block_size=bk,
                         num_blocks=dense_blocks + 1)  # + the trash block
    prefix = np.arange(3, 15, dtype=np.int32)         # 12 tokens = 3 blocks
    prompts = [np.concatenate([prefix, np.asarray([20 + i], np.int32)])
               for i in range(8)]
    pool.admit_slot(0, prompts[0], 3)
    for j in range(3):
        pool.register_block(0, j, prompts[0])
    for s in range(1, 8):
        assert pool.can_admit(s, prompts[s], 3), f"slot {s} refused"
        assert pool.admit_slot(s, prompts[s], 3) == 12
    assert pool.n_prefix_hits == 7
    # all 8 resident inside the 4-dense-slot block budget
    used = {b for blocks in pool.slot_blocks for b in blocks}
    assert len(used) <= dense_blocks


def test_non_shareable_families_degenerate_safely():
    # no attention at all → nothing pages → the pool reports dense
    ssm = make_pool(TINY_CFGS["ssm2"], 2, MAX_SEQ, pool="paged",
                    block_size=BK)
    assert not ssm.is_paged
    # sliding-window ring (window < max_seq) is already bounded → dense
    swa = make_pool(TINY_CFGS["swa"], 2, MAX_SEQ, pool="paged", block_size=BK)
    assert not swa.is_paged
    # hybrid pages its attention K/V but cannot SHARE (recurrent state
    # encodes the prefix outside the blocks)
    hyb = make_pool(TINY_CFGS["hybrid"], 2, MAX_SEQ, pool="paged",
                    block_size=BK)
    assert hyb.is_paged and not hyb.can_share
    assert hyb.lookup_prefix(0, np.arange(3, 20, dtype=np.int32)) == (0, [])


# ------------------------------------------------------------ engine


@pytest.mark.parametrize("family", FAMILIES)
def test_engine_shared_prefix_matches_dense(family):
    """Acceptance: shared-prefix workload, staggered admission — paged token
    streams bit-identical to dense on every family; refcounts return to
    zero after evacuate()."""
    dense = run_staggered(make_engine(family, slots=2),
                          shared_prefix_requests(family, 5))
    eng = make_engine(family, slots=2, pool="paged", block_size=BK)
    paged = run_staggered(eng, shared_prefix_requests(family, 5))
    assert dense == paged
    if eng.pool.is_paged and eng.pool.can_share:
        assert eng.pool.n_prefix_hits > 0          # the sharing actually ran
        lt = eng.lifetime()
        assert lt["prefill_tokens"] == (lt["prompt_tokens"]
                                        - lt["tokens_shared"])
    eng.evacuate()
    if eng.pool.is_paged:
        assert (eng.pool.refcount == 0).all()


def test_engine_pallas_paged_matches_jnp():
    """The Pallas block-gather decode + table-routed scatter (interpret
    mode) must reproduce the jnp paged path's token streams end to end."""
    jnp_streams = run_staggered(
        make_engine("dense", slots=2, pool="paged", block_size=BK),
        shared_prefix_requests("dense", 4))
    pallas_streams = run_staggered(
        make_engine("dense", slots=2, pool="paged", block_size=BK,
                    use_pallas=True),
        shared_prefix_requests("dense", 4))
    assert jnp_streams == pallas_streams


def test_router_downscale_preemption_matches_dense():
    """Mid-run preemption: scale_to(1) evacuates a replica mid-generation;
    the preempted requests rewind and replay on the survivor.  The paged
    fleet's streams must equal the dense fleet's through the preemption."""
    from repro.serving.router import ReplicaRouter

    def run(pool):
        core = core_for("dense")
        router = ReplicaRouter.from_topology(
            core.cfg, "inproc", slots=2, max_seq=MAX_SEQ, prefill_chunk=4,
            n_replicas=2, max_replicas=2, pool=pool, block_size=BK)
        reqs = shared_prefix_requests("dense", 6, gen_len=4)
        done, now = [], 0.0
        for r in reqs[:4]:
            router.submit(r, now=now)
        for _ in range(3):                        # both replicas mid-flight
            now += 1.0
            done.extend(router.step(now))
        router.scale_to(1, now=now)               # preempt + requeue
        for r in reqs[4:]:
            router.submit(r, now=now)
        while len(done) < len(reqs) and now < 400:
            now += 1.0
            done.extend(router.step(now))
        assert len(done) == len(reqs)
        return {r.rid: tuple(r.tokens_out) for r in done}

    assert run("dense") == run("paged")


@pytest.mark.slow
def test_paged_streams_identical_on_proc_topology():
    """Acceptance: the paged engine behind a subprocess worker (pool params
    ride the init RPC) streams bit-identically to the dense inproc engine
    on the shared-prefix workload."""
    from repro.serving import InProcessReplica, ProcessReplica

    cfg = TINY_CFGS["dense"]

    def run(rep):
        try:
            reqs = shared_prefix_requests("dense", 5)
            done, now, i = [], 0.0, 0
            while len(done) < len(reqs) and now < 300:
                if i < len(reqs):
                    rep.submit(reqs[i], now=now)
                    i += 1
                now += 1.0
                done.extend(rep.step(now))
            assert len(done) == len(reqs), (len(done), len(reqs))
            return {r.rid: tuple(r.tokens_out) for r in done}
        finally:
            rep.close()

    dense = run(InProcessReplica.build(cfg, slots=2, max_seq=MAX_SEQ,
                                       prefill_chunk=4))
    paged = run(ProcessReplica(cfg, slots=2, max_seq=MAX_SEQ,
                               prefill_chunk=4, pool="paged", block_size=BK))
    assert dense == paged


_PAGED_SHARDED_SUBPROC = r"""
import numpy as np
import jax
assert len(jax.devices()) == 2, jax.devices()
from repro.models.config import ModelConfig
from repro.serving import InProcessReplica, Request, ShardedReplica
from repro.launch.mesh import make_mesh

cfg = ModelConfig(name="tiny-dense", family="dense", n_layers=2, d_model=32,
                  n_heads=4, n_kv_heads=2, d_ff=64, vocab=64, qkv_bias=True,
                  param_dtype="float32", dtype="float32")
MAX_SEQ, SLOTS, BK = 24, 4, 4

def requests(seed=0):
    rng = np.random.default_rng(seed)
    prefix = rng.integers(3, cfg.vocab, size=8).astype(np.int32)
    return [Request(rid=i, prompt=np.concatenate(
                [prefix,
                 rng.integers(3, cfg.vocab, size=3).astype(np.int32)]),
                gen_len=3) for i in range(5)]

def run(rep):
    reqs = requests()
    done, now, i = [], 0.0, 0
    while len(done) < len(reqs) and now < 300:
        if i < len(reqs):
            rep.submit(reqs[i], now=now)
            i += 1
        now += 1.0
        done.extend(rep.step(now))
    assert len(done) == len(reqs), (len(done), len(reqs))
    return {r.rid: tuple(r.tokens_out) for r in done}

dense = run(InProcessReplica.build(cfg, slots=SLOTS, max_seq=MAX_SEQ,
                                   prefill_chunk=4))
sharded = ShardedReplica(cfg, slots=SLOTS, max_seq=MAX_SEQ, prefill_chunk=4,
                         mesh=make_mesh((2,), ("data",)), pool="paged",
                         block_size=BK)
paged = run(sharded)
assert dense == paged, (dense, paged)
pool = sharded.engine.pool
assert pool.is_paged and pool.partitions == 2
assert pool.n_prefix_hits > 0, pool.n_prefix_hits
print("PAGED_SHARDED_EQ_OK")
"""


@pytest.mark.slow
def test_paged_streams_identical_on_sharded_topology():
    """Acceptance: the paged pool under the 2-device shard_map decode (block
    pool split into per-partition ranges, global→local id fold) streams
    bit-identically to the dense inproc engine, with real prefix hits on
    both partitions' registries.  Re-execs python for the device-count
    override, as in test_replica_fabric."""
    env = os.environ.copy()
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    out = subprocess.run([sys.executable, "-c", _PAGED_SHARDED_SUBPROC],
                         env=env, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "PAGED_SHARDED_EQ_OK" in out.stdout


# ------------------------------------------------- satellite bugfixes


def test_closed_loop_scales_up_when_prefill_chunk_covers_prompt():
    """Regression: with prefill_chunk >= prompt_len the capacity model's
    service time went NEGATIVE (negative capacity → util pinned at 1,
    predicted latency negative → always "meets" the SLO), so the planner
    never scaled above one replica under a spike."""
    from repro.serving.closed_loop import LoopConfig, run_closed_loop
    from repro.sim.serving import WorkloadSpec

    lc = LoopConfig(slots=2, max_replicas=3, max_seq=32, prefill_chunk=8,
                    steps_per_tick=6, spike_rps=8.0)
    spec = WorkloadSpec(prompt_len=4, gen_len=3)    # chunk > prompt
    router, logs = run_closed_loop(TINY_CFGS["dense"], autoscale=True,
                                   ticks=8, seed=0, lc=lc, spec=spec)
    router.close()
    assert max(t.replicas for t in logs) > 1, \
        [(t.replicas, t.reason) for t in logs]


def _report(rid, tick, *, lat=(), n=0, errs=0, util=0.8, qd=0, t_ms=0.0):
    from repro.core.monitoring.collector import ReplicaReport
    return ReplicaReport(replica_id=rid, tick=tick,
                         latency_ms_samples=list(lat), n_requests=n,
                         n_errors=errs, flop_util=util, hbm_util=util,
                         ici_util=util, mem_frac=util, queue_depth=qd,
                         transport_ms=t_ms)


def test_collector_stale_report_not_replayed_at_full_weight():
    """Regression: aggregate() decayed only the four util channels — a
    one-tick-stale report's latency samples, request counts, and queue
    depth replayed at FULL weight, so a silent replica's last window
    inflated fleet throughput and froze the latency percentiles."""
    from repro.core.monitoring.collector import MetricsCollector

    c = MetricsCollector()
    c.submit(_report(0, 0, lat=[500.0] * 4, n=4, errs=2, qd=6, t_ms=8.0))
    fresh = c.aggregate(0, n_replicas=1, max_replicas=4)
    assert fresh["throughput"] == 4 and fresh["latency_p50"] == 500.0
    stale = c.aggregate(1, n_replicas=1, max_replicas=4)   # 1 tick stale
    # events happened once, in tick 0's window — not again
    assert stale["throughput"] == 0.0
    assert stale["error_rate"] == 0.0
    assert stale["latency_p50"] == 0.0
    # gauges decay like the util channels always did
    assert stale["flop_util"] == pytest.approx(0.4)
    assert stale["queue_depth"] == pytest.approx(3.0)
    assert stale["transport_ms"] == pytest.approx(4.0)


def test_collector_prunes_retired_replicas():
    """Regression: rids past max_staleness were skipped but never DELETED —
    reports, error flags, and latency EWMAs grew monotonically over fleet
    churn, and a long-dead errored replica stayed on the straggler feed."""
    from repro.core.monitoring.collector import MetricsCollector

    c = MetricsCollector(max_staleness=4)
    for rid in range(10):
        c.submit(_report(rid, 0, lat=[100.0] * 4, n=4, errs=1))
    assert len(c.reports) == 10 and len(c._errored) == 10
    c.submit(_report(99, 20, lat=[100.0] * 4, n=4))
    c.aggregate(20, n_replicas=1, max_replicas=4)
    assert set(c.reports) == {99}
    assert set(c._errored) == {99}
    assert set(c._lat_ewma) == {99}
    assert c.stragglers() == []          # the dead errored rids aged out


def test_request_sampling_default_not_shared():
    """Regression: the class-level ``sampling: SamplingParams()`` default
    made every Request share ONE instance — safe only while SamplingParams
    stays frozen, and one mutable field away from coupling the fleet."""
    a = Request(rid=0, prompt=np.asarray([3, 4], np.int32), gen_len=1)
    b = Request(rid=1, prompt=np.asarray([3, 4], np.int32), gen_len=1)
    assert a.sampling is not b.sampling
    assert a.sampling == SamplingParams()


def test_collector_counts_report_landing_one_tick_late():
    """Regression: event channels were consumed only when ``stale == 0`` —
    a report landing one aggregate tick late (transport delay, tick
    misalignment) was never counted, permanently undercounting fleet
    throughput and errors."""
    from repro.core.monitoring.collector import MetricsCollector

    c = MetricsCollector()
    c.aggregate(0, n_replicas=1, max_replicas=4)   # report hasn't landed yet
    c.submit(_report(0, 0, lat=[500.0] * 4, n=4, errs=2))
    late = c.aggregate(1, n_replicas=1, max_replicas=4)
    assert late["throughput"] == 4.0
    assert late["error_rate"] == pytest.approx(0.5)
    assert late["latency_p50"] == 500.0
    # consumed exactly once — not replayed on the following tick
    again = c.aggregate(2, n_replicas=1, max_replicas=4)
    assert again["throughput"] == 0.0
    assert again["latency_p50"] == 0.0


def test_workload_sampling_default_not_shared():
    """Regression: synthetic_requests / shared_prefix_requests kept the
    shared default-argument ``SamplingParams()`` instance the Request fix
    just removed — defaulted requests must each own their params."""
    from repro.serving import workload
    from repro.sim.serving import WorkloadSpec

    spec = WorkloadSpec(prompt_len=8, gen_len=2)
    rng = np.random.default_rng(0)
    reqs = workload.synthetic_requests(spec, 3, 64, rng=rng)
    assert len({id(r.sampling) for r in reqs}) == 3
    reqs = workload.shared_prefix_requests(spec, 3, 64, prefix_len=4, rng=rng)
    assert len({id(r.sampling) for r in reqs}) == 3
    # an explicitly passed instance is still honored as-is
    sp = SamplingParams(temperature=0.7, seed=1)
    reqs = workload.synthetic_requests(spec, 2, 64, rng=rng, sampling=sp)
    assert all(r.sampling is sp for r in reqs)


def test_prefix_key_mixes_patch_content():
    """Prefix KV for the VLM family depends on the vision patches, not just
    the prompt token ids — identical token prefixes with different patch
    content must never alias in the prefix registry."""
    pool = PagedSlotPool(TINY_CFGS["vlm"], 2, MAX_SEQ, block_size=BK)
    assert pool.can_share
    prompt = np.arange(3, 14, dtype=np.int32)
    pool.admit_slot(0, prompt, 3, extra=b"patches-a")
    for j in range(2):
        pool.register_block(0, j, prompt, extra=b"patches-a")
    assert pool.lookup_prefix(1, prompt, extra=b"patches-b") == (0, [])
    assert pool.lookup_prefix(1, prompt, extra=b"patches-a")[0] == 2
    # the engine threads a digest of the patches it actually feeds
    eng = make_engine("vlm", pool="paged", block_size=BK)
    assert eng._patch_key != b""


def test_pool_geometry_default_block_size_divides_max_seq():
    """Regression: the default block size min(8, max_seq) was asserted to
    divide max_seq, so pool="paged" with e.g. max_seq=12 and no explicit
    block_size crashed at construction."""
    from repro.serving.slots import pool_geometry

    bk, _ = pool_geometry(2, 12)
    assert bk == 6                       # largest divisor of 12 that is <= 8
    assert pool_geometry(2, 7)[0] == 7   # prime: falls back to max_seq itself
    pool = PagedSlotPool(TINY_CFGS["dense"], 2, 12)   # constructs fine
    assert pool.block_size == 6
    # an explicit non-divisor names the knob instead of a bare assert
    with pytest.raises(ValueError, match="block_size"):
        pool_geometry(2, 12, block_size=5)
